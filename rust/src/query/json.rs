//! Minimal JSON parser + serializer (RFC 8259 subset, no external
//! crates — `serde_json` is not available offline).
//!
//! Supports the full JSON data model: objects, arrays, strings with
//! escapes (incl. `\uXXXX` with surrogate pairs), numbers, booleans,
//! null. Numbers are kept as f64 (adequate for query thresholds and
//! manifest shapes).

use crate::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Objects use a BTreeMap: deterministic order for
/// stable serialization (queries are hashed into job ids).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (kept as f64).
    Num(f64),
    /// A string (escapes already decoded).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (rejects trailing characters).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    /// The object map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `get` that errors with a path-aware message (query validation).
    pub fn require(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::query(format!("missing required field '{key}'")))
    }

    /// Required string-typed field (path-aware error).
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.require(key)?
            .as_str()
            .ok_or_else(|| Error::query(format!("field '{key}' must be a string")))
    }

    /// Required number-typed field (path-aware error).
    pub fn num_field(&self, key: &str) -> Result<f64> {
        self.require(key)?
            .as_f64()
            .ok_or_else(|| Error::query(format!("field '{key}' must be a number")))
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building manifests / responses.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::query(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{kw}'")))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let c = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(cp).ok_or_else(|| self.err("bad code point"))?
                        } else if (0xDC00..0xE000).contains(&hi) {
                            return Err(self.err("unexpected low surrogate"));
                        } else {
                            char::from_u32(hi).ok_or_else(|| self.err("bad code point"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let extra = match c {
                            0xC0..=0xDF => 1,
                            0xE0..=0xEF => 2,
                            0xF0..=0xF7 => 3,
                            _ => return Err(self.err("invalid utf-8")),
                        };
                        self.pos += extra;
                        let bytes = self
                            .b
                            .get(start..self.pos)
                            .ok_or_else(|| self.err("truncated utf-8"))?;
                        let st = std::str::from_utf8(bytes)
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(st);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = (v << 4) | d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.25e2").unwrap(), Json::Num(-325.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "d");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\bAé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\bAé");
        // surrogate pair: 😀 U+1F600
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
        // raw multibyte passes through
        let v = Json::parse("\"héllo😀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo😀");
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "}", "[1,]", "{\"a\":}", "{\"a\" 1}", "tru", "01x", "\"\\q\"",
            "\"unterminated", "{\"a\":1} trailing", "\"\\ud800\"", "[1 2]",
        ] {
            assert!(Json::parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn deep_nesting_bounded() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&deep).is_err());
        let ok = "[".repeat(50) + &"]".repeat(50);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn serialize_roundtrip() {
        let src = r#"{"branches":["Electron_pt","HLT_*"],"cut":{"op":">","value":25},"force_all":false,"n":3}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        let v2 = Json::parse(&out).unwrap();
        assert_eq!(v, v2);
        assert!(out.contains("\"force_all\":false"));
        assert!(out.contains("\"value\":25"));
    }

    #[test]
    fn typed_accessors_and_errors() {
        let v = Json::parse(r#"{"s":"x","n":1.5,"b":true}"#).unwrap();
        assert_eq!(v.str_field("s").unwrap(), "x");
        assert_eq!(v.num_field("n").unwrap(), 1.5);
        assert!(v.str_field("n").is_err());
        assert!(v.num_field("missing").is_err());
        assert!(v.require("missing").is_err());
    }

    #[test]
    fn float_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(-0.25).to_string(), "-0.25");
    }

    #[test]
    fn prop_roundtrip_random_values() {
        crate::util::prop_check("json-roundtrip", 30, |rng| {
            fn gen(rng: &mut crate::util::Pcg32, depth: usize) -> Json {
                match if depth > 3 { rng.below(4) } else { rng.below(6) } {
                    0 => Json::Null,
                    1 => Json::Bool(rng.chance(0.5)),
                    2 => Json::Num((rng.next_u32() as f64 / 64.0).round() / 16.0),
                    3 => Json::Str(format!("s{}_é😀", rng.next_u32())),
                    4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth + 1)).collect()),
                    _ => Json::Obj(
                        (0..rng.below(5))
                            .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                            .collect(),
                    ),
                }
            }
            let v = gen(rng, 0);
            let text = v.to_string();
            let back = Json::parse(&text).unwrap();
            assert_eq!(v, back, "text={text}");
        });
    }
}
