//! The dataset specification: what a skim job reads.
//!
//! SkimROOT's premise is *dataset*-scale reduction — the paper filters
//! an LHC dataset, not a file — and real HEP reductions iterate
//! catalogs of thousands of files. [`DatasetSpec`] makes the dataset
//! the first-class input unit of a [`super::SkimQuery`]:
//!
//! * [`DatasetSpec::File`] — one catalog-relative file, the legacy
//!   single-file job (exact pre-dataset behavior, byte-for-byte);
//! * [`DatasetSpec::Files`] — an explicit ordered file list;
//! * [`DatasetSpec::Glob`] — a glob pattern expanded against the
//!   storage export at planning time (`store/*.troot`);
//! * [`DatasetSpec::Catalog`] — a named catalog: a `<name>.catalog`
//!   text file in the storage root listing one file per line.
//!
//! The spec is *lexical*: it names files but does not touch storage.
//! Resolution against a storage root — listing globs, reading catalog
//! files, and the path-traversal validation gate — lives in
//! [`crate::catalog`].
//!
//! In the JSON payload the `"input"` field stays a string for
//! single-file, glob and catalog specs (legacy payloads parse and
//! reserialize byte-for-byte), and becomes an array of strings for an
//! explicit file list.

use std::fmt;

/// What a query reads: one file, an explicit list, a glob over the
/// storage export, or a named catalog. See the module docs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DatasetSpec {
    /// One catalog-relative file path (the legacy single-file job).
    File(String),
    /// An explicit ordered list of catalog-relative file paths.
    Files(Vec<String>),
    /// A glob pattern (`*`, `?`) expanded against the storage export.
    Glob(String),
    /// A named catalog: `<name>.catalog` in the storage root, one
    /// file per line (`#` comments allowed).
    Catalog(String),
}

impl DatasetSpec {
    /// Parse the string spelling of a spec: `catalog:NAME` names a
    /// catalog, anything containing a glob metacharacter (`*`, `?`)
    /// is a glob, everything else is a single file path.
    ///
    /// ```
    /// use skimroot::query::DatasetSpec;
    ///
    /// assert_eq!(DatasetSpec::parse("events.troot"), DatasetSpec::File("events.troot".into()));
    /// assert_eq!(DatasetSpec::parse("store/*.troot"), DatasetSpec::Glob("store/*.troot".into()));
    /// assert_eq!(DatasetSpec::parse("catalog:run2018"), DatasetSpec::Catalog("run2018".into()));
    /// ```
    pub fn parse(s: &str) -> DatasetSpec {
        if let Some(name) = s.strip_prefix("catalog:") {
            DatasetSpec::Catalog(name.to_string())
        } else if s.contains(['*', '?']) {
            DatasetSpec::Glob(s.to_string())
        } else {
            DatasetSpec::File(s.to_string())
        }
    }

    /// The single file path when this is a legacy single-file spec.
    pub fn as_single(&self) -> Option<&str> {
        match self {
            DatasetSpec::File(p) => Some(p),
            _ => None,
        }
    }

    /// True for the legacy single-file spec (the exact pre-dataset job
    /// contract; multi-file specs go through the dataset layer).
    pub fn is_single(&self) -> bool {
        matches!(self, DatasetSpec::File(_))
    }

    /// The single file path, erroring for multi-file specs — used by
    /// execution layers that operate strictly per file (the engine,
    /// the DPU node): the coordinator decomposes dataset jobs into
    /// per-file queries before they reach those layers.
    pub fn single_path(&self) -> crate::Result<&str> {
        self.as_single().ok_or_else(|| {
            crate::Error::Engine(format!(
                "dataset spec '{self}' reached a single-file execution path \
                 (the coordinator should have decomposed it per file)"
            ))
        })
    }
}

impl fmt::Display for DatasetSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DatasetSpec::File(p) | DatasetSpec::Glob(p) => f.write_str(p),
            DatasetSpec::Catalog(name) => write!(f, "catalog:{name}"),
            DatasetSpec::Files(files) => f.write_str(&files.join(",")),
        }
    }
}

impl From<&str> for DatasetSpec {
    fn from(s: &str) -> Self {
        DatasetSpec::parse(s)
    }
}

impl From<String> for DatasetSpec {
    fn from(s: String) -> Self {
        DatasetSpec::parse(&s)
    }
}

impl From<&String> for DatasetSpec {
    fn from(s: &String) -> Self {
        DatasetSpec::parse(s)
    }
}

impl From<Vec<String>> for DatasetSpec {
    fn from(files: Vec<String>) -> Self {
        DatasetSpec::Files(files)
    }
}

impl From<&[&str]> for DatasetSpec {
    fn from(files: &[&str]) -> Self {
        DatasetSpec::Files(files.iter().map(|f| f.to_string()).collect())
    }
}

// Keep `assert_eq!(query.input, "events.troot")`-style comparisons
// (and ordinary call sites) working across the String → DatasetSpec
// refactor: a spec equals the string it parses from. `Files` has no
// string spelling (its display form is lossy), so it never equals
// one — compare explicit lists as specs, not strings.
impl PartialEq<str> for DatasetSpec {
    fn eq(&self, other: &str) -> bool {
        match self {
            DatasetSpec::File(p) | DatasetSpec::Glob(p) => p == other,
            DatasetSpec::Catalog(name) => {
                other.strip_prefix("catalog:") == Some(name.as_str())
            }
            DatasetSpec::Files(_) => false,
        }
    }
}

impl PartialEq<&str> for DatasetSpec {
    fn eq(&self, other: &&str) -> bool {
        <DatasetSpec as PartialEq<str>>::eq(self, other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_classifies_specs() {
        assert_eq!(DatasetSpec::parse("a/b.troot"), DatasetSpec::File("a/b.troot".into()));
        assert_eq!(DatasetSpec::parse("a/*.troot"), DatasetSpec::Glob("a/*.troot".into()));
        assert_eq!(DatasetSpec::parse("part?.troot"), DatasetSpec::Glob("part?.troot".into()));
        assert_eq!(DatasetSpec::parse("catalog:x"), DatasetSpec::Catalog("x".into()));
    }

    #[test]
    fn display_roundtrips_through_parse() {
        for spec in [
            DatasetSpec::File("events.troot".into()),
            DatasetSpec::Glob("store/*.troot".into()),
            DatasetSpec::Catalog("run2018".into()),
        ] {
            assert_eq!(DatasetSpec::parse(&spec.to_string()), spec);
        }
    }

    #[test]
    fn single_path_accessors() {
        let f = DatasetSpec::File("x.troot".into());
        assert!(f.is_single());
        assert_eq!(f.as_single(), Some("x.troot"));
        assert_eq!(f.single_path().unwrap(), "x.troot");
        let g = DatasetSpec::Glob("*.troot".into());
        assert!(!g.is_single());
        assert!(g.as_single().is_none());
        assert!(g.single_path().is_err());
    }

    #[test]
    fn from_impls() {
        assert_eq!(DatasetSpec::from("a.troot"), DatasetSpec::File("a.troot".into()));
        assert_eq!(
            DatasetSpec::from(vec!["a".to_string(), "b".to_string()]),
            DatasetSpec::Files(vec!["a".into(), "b".into()])
        );
        assert_eq!(DatasetSpec::File("a.troot".into()), "a.troot");
    }
}
