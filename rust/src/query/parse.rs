//! TCut-style cut-string frontend for the query IR.
//!
//! Parses selection strings in the dialect ROOT users write —
//! `"nElectron >= 1 && abs(Electron_eta) < 2.4 && (MET_pt > 100 || ht(30) > 200)"`
//! — into the open [`Expr`] AST. Exposed via CLI `--cut` and the JSON
//! payload's `"cut"` field.
//!
//! Grammar (precedence low → high; all binary operators at one level
//! are left-associative, comparisons do not chain):
//!
//! ```text
//! or     := and  ( '||' and )*
//! and    := cmp  ( '&&' cmp )*
//! cmp    := addx ( ('<'|'<='|'>'|'>='|'=='|'!=') addx )?
//! addx   := mulx ( ('+'|'-') mulx )*
//! mulx   := unary ( ('*'|'/') unary )*
//! unary  := '!' unary | '-' unary | primary
//! primary:= NUMBER
//!         | '(' or ')'
//!         | '|' or '|'                       -- absolute value bars
//!         | IDENT                            -- branch reference
//!         | IDENT '(' args ')'               -- function / aggregation
//! ```
//!
//! Functions: `abs(x)`; two-argument `min(a, b)` / `max(a, b)`;
//! aggregations `count(pred)`, `any(pred)`, `all(pred)`, `sum(x)`,
//! `max(x)`, `min(x)` — each also accepting a `x[pred]` selection
//! subscript (e.g. `sum(Jet_pt[Jet_pt > 30])`, `count(Jet_eta <
//! 0[Jet_pt > 30])`); the subscript is only valid directly inside an
//! aggregation call; and the derived event variables `ht(ptmin)` =
//! `sum(Jet_pt[Jet_pt > ptmin])` and `njets(ptmin)` =
//! `count(Jet_pt > ptmin)` (NanoAOD-convention jet collection).
//!
//! Limitation: inside absolute-value bars use `abs(...)` rather than a
//! nested `||` (two adjacent pipes always lex as the or-operator).

use super::expr::{AggOp, BinOp, Expr};
use crate::{Error, Result};

/// Nesting bound: cut strings arrive over the DPU HTTP service, so
/// recursion depth must be bounded (a stack overflow aborts the
/// process). Mirrors the JSON parser's depth cap.
const MAX_DEPTH: usize = 128;

/// Parse a cut string into the query IR.
pub fn parse_cut(text: &str) -> Result<Expr> {
    let tokens = lex(text)?;
    let mut p = Parser { tokens, pos: 0, src_len: text.len(), depth: 0 };
    let expr = p.or_expr()?;
    match p.peek() {
        None => Ok(expr),
        Some(_) => Err(p.err("expected end of cut string")),
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Num(f64),
    Ident(String),
    Lt,
    Le,
    Gt,
    Ge,
    EqEq,
    NeEq,
    AndAnd,
    OrOr,
    Bang,
    Plus,
    Minus,
    Star,
    Slash,
    LParen,
    RParen,
    LBrack,
    RBrack,
    Pipe,
    Comma,
}

impl Tok {
    fn describe(&self) -> String {
        match self {
            Tok::Num(v) => format!("number {v}"),
            Tok::Ident(s) => format!("identifier '{s}'"),
            Tok::Lt => "'<'".into(),
            Tok::Le => "'<='".into(),
            Tok::Gt => "'>'".into(),
            Tok::Ge => "'>='".into(),
            Tok::EqEq => "'=='".into(),
            Tok::NeEq => "'!='".into(),
            Tok::AndAnd => "'&&'".into(),
            Tok::OrOr => "'||'".into(),
            Tok::Bang => "'!'".into(),
            Tok::Plus => "'+'".into(),
            Tok::Minus => "'-'".into(),
            Tok::Star => "'*'".into(),
            Tok::Slash => "'/'".into(),
            Tok::LParen => "'('".into(),
            Tok::RParen => "')'".into(),
            Tok::LBrack => "'['".into(),
            Tok::RBrack => "']'".into(),
            Tok::Pipe => "'|'".into(),
            Tok::Comma => "','".into(),
        }
    }
}

fn lex(text: &str) -> Result<Vec<(Tok, usize)>> {
    let b = text.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    let err =
        |pos: usize, msg: String| Error::query(format!("cut parse error at char {pos}: {msg}"));
    while i < b.len() {
        let c = b[i];
        match c {
            b' ' | b'\t' | b'\n' | b'\r' => i += 1,
            b'(' => {
                out.push((Tok::LParen, i));
                i += 1;
            }
            b')' => {
                out.push((Tok::RParen, i));
                i += 1;
            }
            b'[' => {
                out.push((Tok::LBrack, i));
                i += 1;
            }
            b']' => {
                out.push((Tok::RBrack, i));
                i += 1;
            }
            b',' => {
                out.push((Tok::Comma, i));
                i += 1;
            }
            b'+' => {
                out.push((Tok::Plus, i));
                i += 1;
            }
            b'-' => {
                out.push((Tok::Minus, i));
                i += 1;
            }
            b'*' => {
                out.push((Tok::Star, i));
                i += 1;
            }
            b'/' => {
                out.push((Tok::Slash, i));
                i += 1;
            }
            b'<' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push((Tok::Le, i));
                    i += 2;
                } else {
                    out.push((Tok::Lt, i));
                    i += 1;
                }
            }
            b'>' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push((Tok::Ge, i));
                    i += 2;
                } else {
                    out.push((Tok::Gt, i));
                    i += 1;
                }
            }
            b'=' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push((Tok::EqEq, i));
                    i += 2;
                } else {
                    return Err(err(i, "single '=' is not an operator (use '==')".into()));
                }
            }
            b'!' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push((Tok::NeEq, i));
                    i += 2;
                } else {
                    out.push((Tok::Bang, i));
                    i += 1;
                }
            }
            b'&' => {
                if b.get(i + 1) == Some(&b'&') {
                    out.push((Tok::AndAnd, i));
                    i += 2;
                } else {
                    return Err(err(i, "single '&' is not an operator (use '&&')".into()));
                }
            }
            b'|' => {
                if b.get(i + 1) == Some(&b'|') {
                    out.push((Tok::OrOr, i));
                    i += 2;
                } else {
                    out.push((Tok::Pipe, i));
                    i += 1;
                }
            }
            b'0'..=b'9' => {
                let start = i;
                while matches!(b.get(i), Some(c) if c.is_ascii_digit()) {
                    i += 1;
                }
                if b.get(i) == Some(&b'.') {
                    i += 1;
                    while matches!(b.get(i), Some(c) if c.is_ascii_digit()) {
                        i += 1;
                    }
                }
                if matches!(b.get(i), Some(b'e' | b'E')) {
                    i += 1;
                    if matches!(b.get(i), Some(b'+' | b'-')) {
                        i += 1;
                    }
                    while matches!(b.get(i), Some(c) if c.is_ascii_digit()) {
                        i += 1;
                    }
                }
                let s = &text[start..i];
                let v = s
                    .parse::<f64>()
                    .map_err(|_| err(start, format!("bad number '{s}'")))?;
                // f64 parsing saturates overflow to infinity, which
                // would not survive the canonical Display↔parse
                // round-trip — reject it at the source.
                if !v.is_finite() {
                    return Err(err(start, format!("number literal '{s}' out of range")));
                }
                out.push((Tok::Num(v), start));
            }
            b'A'..=b'Z' | b'a'..=b'z' | b'_' => {
                let start = i;
                while matches!(b.get(i), Some(c) if c.is_ascii_alphanumeric() || *c == b'_') {
                    i += 1;
                }
                out.push((Tok::Ident(text[start..i].to_string()), start));
            }
            other => {
                return Err(err(i, format!("unexpected character '{}'", other as char)));
            }
        }
    }
    Ok(out)
}

struct Parser {
    tokens: Vec<(Tok, usize)>,
    pos: usize,
    src_len: usize,
    depth: usize,
}

impl Parser {
    fn err(&self, msg: &str) -> Error {
        let (at, got) = match self.tokens.get(self.pos) {
            Some((tok, pos)) => (*pos, format!(" (found {})", tok.describe())),
            None => (self.src_len, " (found end of input)".to_string()),
        };
        Error::query(format!("cut parse error at char {at}: {msg}{got}"))
    }

    fn peek(&self) -> Option<&Tok> {
        self.tokens.get(self.pos).map(|(t, _)| t)
    }

    fn bump(&mut self) -> Option<Tok> {
        let t = self.tokens.get(self.pos).map(|(t, _)| t.clone());
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn eat(&mut self, tok: &Tok) -> bool {
        if self.peek() == Some(tok) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn expect(&mut self, tok: &Tok, what: &str) -> Result<()> {
        if self.eat(tok) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {what}")))
        }
    }

    /// Depth guard covering both recursion cycles (`primary` →
    /// `or_expr` for parens/bars/calls, and `unary` → `unary` for
    /// `!`/`-` chains).
    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            Err(self.err("cut expression nesting too deep"))
        } else {
            Ok(())
        }
    }

    fn or_expr(&mut self) -> Result<Expr> {
        self.enter()?;
        let r = self.or_expr_inner();
        self.depth -= 1;
        r
    }

    fn or_expr_inner(&mut self) -> Result<Expr> {
        let mut lhs = self.and_expr()?;
        while self.eat(&Tok::OrOr) {
            let rhs = self.and_expr()?;
            lhs = lhs.or(rhs);
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.cmp_expr()?;
        while self.eat(&Tok::AndAnd) {
            let rhs = self.cmp_expr()?;
            lhs = lhs.and(rhs);
        }
        Ok(lhs)
    }

    fn cmp_op(&self) -> Option<BinOp> {
        match self.peek() {
            Some(Tok::Lt) => Some(BinOp::Lt),
            Some(Tok::Le) => Some(BinOp::Le),
            Some(Tok::Gt) => Some(BinOp::Gt),
            Some(Tok::Ge) => Some(BinOp::Ge),
            Some(Tok::EqEq) => Some(BinOp::Eq),
            Some(Tok::NeEq) => Some(BinOp::Ne),
            _ => None,
        }
    }

    fn cmp_expr(&mut self) -> Result<Expr> {
        let lhs = self.add_expr()?;
        let Some(op) = self.cmp_op() else { return Ok(lhs) };
        self.pos += 1;
        let rhs = self.add_expr()?;
        if self.cmp_op().is_some() {
            return Err(self.err("comparisons do not chain; use '&&' (e.g. 'a < b && b < c')"));
        }
        Ok(Expr::Binary(op, Box::new(lhs), Box::new(rhs)))
    }

    fn add_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.mul_expr()?;
        loop {
            if self.eat(&Tok::Plus) {
                lhs = lhs + self.mul_expr()?;
            } else if self.eat(&Tok::Minus) {
                lhs = lhs - self.mul_expr()?;
            } else {
                return Ok(lhs);
            }
        }
    }

    fn mul_expr(&mut self) -> Result<Expr> {
        let mut lhs = self.unary()?;
        loop {
            if self.eat(&Tok::Star) {
                lhs = lhs * self.unary()?;
            } else if self.eat(&Tok::Slash) {
                lhs = lhs / self.unary()?;
            } else {
                return Ok(lhs);
            }
        }
    }

    fn unary(&mut self) -> Result<Expr> {
        self.enter()?;
        let r = self.unary_inner();
        self.depth -= 1;
        r
    }

    fn unary_inner(&mut self) -> Result<Expr> {
        if self.eat(&Tok::Bang) {
            return Ok(!self.unary()?);
        }
        if self.eat(&Tok::Minus) {
            // `-` folds into numeric literals (see `Neg` on `Expr`).
            return Ok(-self.unary()?);
        }
        self.primary()
    }

    fn primary(&mut self) -> Result<Expr> {
        match self.peek().cloned() {
            Some(Tok::Num(v)) => {
                self.pos += 1;
                Ok(Expr::Num(v))
            }
            Some(Tok::LParen) => {
                self.pos += 1;
                let e = self.or_expr()?;
                self.expect(&Tok::RParen, "')'")?;
                Ok(e)
            }
            Some(Tok::Pipe) => {
                self.pos += 1;
                let e = self.or_expr()?;
                self.expect(&Tok::Pipe, "closing '|'")?;
                Ok(e.abs())
            }
            Some(Tok::Ident(name)) => {
                self.pos += 1;
                if self.eat(&Tok::LParen) {
                    self.call(&name)
                } else {
                    Ok(Expr::Branch(name))
                }
            }
            _ => Err(self.err("expected an expression")),
        }
    }

    /// Parse `expr` with an optional `[pred]` selection subscript.
    fn agg_arg(&mut self) -> Result<(Expr, Option<Expr>)> {
        let arg = self.or_expr()?;
        if self.eat(&Tok::LBrack) {
            let pred = self.or_expr()?;
            self.expect(&Tok::RBrack, "']'")?;
            Ok((arg, Some(pred)))
        } else {
            Ok((arg, None))
        }
    }

    /// `name` has been consumed along with its opening paren.
    fn call(&mut self, name: &str) -> Result<Expr> {
        let expr = match name {
            "abs" => {
                let e = self.or_expr()?;
                e.abs()
            }
            // Arity disambiguates: `min(a, b)` is the two-argument
            // function, `min(x)` / `min(x[p])` the aggregation.
            "min" | "max" => {
                let (first, pred) = self.agg_arg()?;
                if self.eat(&Tok::Comma) {
                    if pred.is_some() {
                        return Err(
                            self.err("selection subscript is not valid in two-argument min/max")
                        );
                    }
                    let second = self.or_expr()?;
                    if name == "min" {
                        first.min(second)
                    } else {
                        first.max(second)
                    }
                } else {
                    let op = if name == "min" { AggOp::Min } else { AggOp::Max };
                    Expr::agg(op, first, pred)
                }
            }
            "sum" => {
                let (arg, pred) = self.agg_arg()?;
                Expr::agg(AggOp::Sum, arg, pred)
            }
            // The argument is the predicate; an optional `[pred]`
            // subscript adds a selection filter on top.
            "count" | "any" | "all" => {
                let (arg, pred) = self.agg_arg()?;
                let op = match name {
                    "count" => AggOp::Count,
                    "any" => AggOp::Any,
                    _ => AggOp::All,
                };
                Expr::agg(op, arg, pred)
            }
            // Derived event variables (NanoAOD conventions).
            "ht" => {
                let ptmin = self.or_expr()?;
                Expr::sum_if(Expr::branch("Jet_pt"), Expr::branch("Jet_pt").gt(ptmin))
            }
            "njets" => {
                let ptmin = self.or_expr()?;
                Expr::count(Expr::branch("Jet_pt").gt(ptmin))
            }
            other => {
                return Err(self.err(&format!(
                    "unknown function '{other}' (known: abs, min, max, sum, count, any, all, \
                     ht, njets)"
                )));
            }
        };
        self.expect(&Tok::RParen, "')'")?;
        Ok(expr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::expr::Expr as E;

    fn p(text: &str) -> Expr {
        parse_cut(text).unwrap_or_else(|e| panic!("parse '{text}': {e}"))
    }

    #[test]
    fn precedence_table() {
        // Multiplication binds tighter than addition.
        assert_eq!(p("1 + 2 * 3"), E::num(1.0) + (E::num(2.0) * E::num(3.0)));
        // Addition binds tighter than comparison.
        assert_eq!(p("a + 1 > b"), (E::branch("a") + 1.0).gt(E::branch("b")));
        // Comparison binds tighter than `&&`, which binds tighter than `||`.
        assert_eq!(
            p("a > 1 && b < 2 || c == 3"),
            E::branch("a").gt(1.0).and(E::branch("b").lt(2.0)).or(E::branch("c").eq(3.0))
        );
        // Unary binds tighter than binary.
        assert_eq!(p("-a * b"), (-E::branch("a")) * E::branch("b"));
        assert_eq!(p("!a && b"), (!E::branch("a")).and(E::branch("b")));
        // Parens override.
        assert_eq!(p("(1 + 2) * 3"), (E::num(1.0) + E::num(2.0)) * E::num(3.0));
    }

    #[test]
    fn associativity_table() {
        // Left-associative chains.
        assert_eq!(p("10 - 3 - 2"), (E::num(10.0) - E::num(3.0)) - E::num(2.0));
        assert_eq!(p("8 / 4 / 2"), (E::num(8.0) / E::num(4.0)) / E::num(2.0));
        assert_eq!(
            p("a && b && c"),
            E::branch("a").and(E::branch("b")).and(E::branch("c"))
        );
        assert_eq!(
            p("a || b || c"),
            E::branch("a").or(E::branch("b")).or(E::branch("c"))
        );
    }

    #[test]
    fn abs_bars_and_abs_call_agree() {
        assert_eq!(p("|Electron_eta| < 2.4"), p("abs(Electron_eta) < 2.4"));
        assert_eq!(p("|a - b| > 1"), (E::branch("a") - E::branch("b")).abs().gt(1.0));
    }

    #[test]
    fn aggregations_and_subscript() {
        assert_eq!(
            p("sum(Jet_pt[Jet_pt > 30]) >= 200"),
            E::sum_if(E::branch("Jet_pt"), E::branch("Jet_pt").gt(30.0)).ge(200.0)
        );
        assert_eq!(
            p("count(Jet_pt > 30) >= 2"),
            E::count(E::branch("Jet_pt").gt(30.0)).ge(2.0)
        );
        assert_eq!(p("any(Muon_pt > 20)"), E::any(E::branch("Muon_pt").gt(20.0)));
        assert_eq!(p("all(Muon_tightId == 1)"), E::all(E::branch("Muon_tightId").eq(1.0)));
        // count/any/all accept a selection subscript too.
        assert_eq!(
            p("count(Jet_eta < 0[Jet_pt > 30])"),
            E::agg(
                AggOp::Count,
                E::branch("Jet_eta").lt(0.0),
                Some(E::branch("Jet_pt").gt(30.0))
            )
        );
        // Arity disambiguation for min/max.
        assert_eq!(p("max(Muon_pt)"), E::max_of(E::branch("Muon_pt")));
        assert_eq!(p("max(a, b)"), E::branch("a").max(E::branch("b")));
        assert_eq!(
            p("min(Jet_eta[Jet_pt > 30])"),
            E::agg(AggOp::Min, E::branch("Jet_eta"), Some(E::branch("Jet_pt").gt(30.0)))
        );
    }

    #[test]
    fn derived_event_variables_expand() {
        assert_eq!(
            p("ht(30) > 200"),
            E::sum_if(E::branch("Jet_pt"), E::branch("Jet_pt").gt(30.0)).gt(200.0)
        );
        assert_eq!(p("njets(45) >= 4"), E::count(E::branch("Jet_pt").gt(45.0)).ge(4.0));
    }

    #[test]
    fn numbers_and_negation() {
        assert_eq!(p("-3.5"), E::Num(-3.5));
        assert_eq!(p("1e3"), E::Num(1000.0));
        assert_eq!(p("2.5e-2"), E::Num(0.025));
        assert_eq!(p("- x"), -E::branch("x"));
    }

    #[test]
    fn issue_example_parses() {
        let e = p("nElectron >= 1 && |Electron_eta| < 2.4 && (MET_pt > 100 || ht(30) > 200)");
        assert_eq!(
            e.branches(),
            vec!["nElectron", "Electron_eta", "MET_pt", "Jet_pt"]
        );
    }

    #[test]
    fn malformed_inputs_error_with_position() {
        for (bad, needle) in [
            ("", "expected an expression"),
            ("a &&", "expected an expression"),
            ("(a > 1", "expected ')'"),
            ("a > 1)", "expected end"),
            ("a = 1", "use '=='"),
            ("a & b", "use '&&'"),
            ("a < b < c", "do not chain"),
            ("foo(1)", "unknown function 'foo'"),
            ("count(", "expected an expression"),
            ("sum(x[y)", "expected ']'"),
            ("min(a[p], b)", "not valid in two-argument"),
            ("a $ b", "unexpected character '$'"),
            ("|a| |b|", "expected end"),
            ("1e999", "out of range"),
        ] {
            let err = parse_cut(bad).unwrap_err();
            let msg = format!("{err}");
            assert!(msg.contains("cut parse error at char"), "{bad}: {msg}");
            assert!(msg.contains(needle), "'{bad}' should mention '{needle}', got: {msg}");
        }
    }

    #[test]
    fn deep_nesting_bounded() {
        // Untrusted cut strings (DPU HTTP) must not overflow the
        // stack: both paren nesting and unary chains are bounded.
        let deep_parens = "(".repeat(100_000) + "x" + &")".repeat(100_000);
        let err = parse_cut(&deep_parens).unwrap_err();
        assert!(format!("{err}").contains("nesting too deep"), "{err}");
        let deep_bangs = "!".repeat(100_000) + "x";
        let err = parse_cut(&deep_bangs).unwrap_err();
        assert!(format!("{err}").contains("nesting too deep"), "{err}");
        // Reasonable nesting still parses.
        let ok = "(".repeat(40) + "x" + &")".repeat(40);
        assert!(parse_cut(&ok).is_ok());
    }

    #[test]
    fn prop_display_reparse_roundtrip() {
        use crate::util::Pcg32;
        fn gen(rng: &mut Pcg32, depth: usize, obj_ctx: bool) -> Expr {
            let branch = |rng: &mut Pcg32| {
                let names = ["Jet_pt", "Muon_eta", "MET_pt", "nJet", "HLT_X"];
                E::branch(names[rng.below(names.len() as u32) as usize])
            };
            let num = |rng: &mut Pcg32| {
                // Grid-quantized values avoid float-print edge cases
                // while still covering negatives and fractions.
                E::num((rng.below(4000) as f64 - 2000.0) / 16.0)
            };
            if depth >= 4 {
                return if rng.chance(0.5) { branch(rng) } else { num(rng) };
            }
            match rng.below(10) {
                0 => num(rng),
                1 | 2 => branch(rng),
                3 => {
                    let inner = gen(rng, depth + 1, obj_ctx);
                    match rng.below(3) {
                        0 => inner.abs(),
                        1 => !inner,
                        _ => -inner,
                    }
                }
                4..=7 => {
                    let a = gen(rng, depth + 1, obj_ctx);
                    let b = gen(rng, depth + 1, obj_ctx);
                    match rng.below(14) {
                        0 => a + b,
                        1 => a - b,
                        2 => a * b,
                        3 => a / b,
                        4 => a.lt(b),
                        5 => a.le(b),
                        6 => a.gt(b),
                        7 => a.ge(b),
                        8 => a.eq(b),
                        9 => a.ne(b),
                        10 => a.and(b),
                        11 => a.or(b),
                        12 => a.min(b),
                        _ => a.max(b),
                    }
                }
                _ => {
                    // Aggregations only one level deep in object context.
                    if obj_ctx {
                        return branch(rng);
                    }
                    let arg = gen(rng, depth + 1, true);
                    match rng.below(6) {
                        0 => E::count(arg),
                        1 => E::any(arg),
                        2 => E::all(arg),
                        3 => E::sum(arg),
                        4 => E::sum_if(arg, gen(rng, depth + 1, true)),
                        _ => E::agg(
                            if rng.chance(0.5) { AggOp::Max } else { AggOp::Min },
                            arg,
                            if rng.chance(0.5) {
                                Some(gen(rng, depth + 1, true))
                            } else {
                                None
                            },
                        ),
                    }
                }
            }
        }
        crate::util::prop_check("cut-string-roundtrip", 60, |rng| {
            let e = gen(rng, 0, false);
            let text = e.to_string();
            let back = parse_cut(&text)
                .unwrap_or_else(|err| panic!("reparse failed for '{text}': {err}"));
            assert_eq!(back, e, "text={text}");
        });
    }
}
