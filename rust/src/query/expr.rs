//! Layer 0 of the query surface: the open expression IR.
//!
//! A [`Expr`] is a typed AST over event data — the open replacement for
//! the closed Figure-2c selection schema. It covers literals, branch
//! references (scalar *and* jagged), arithmetic, comparisons, boolean
//! structure (`&&` / `||` / `!`), `abs`/`min`/`max`, and
//! jagged-collection aggregations (`count`, `sum`, `any`, `all`,
//! `max`, `min`). The legacy structured selection lowers onto this IR
//! ([`crate::query::ast::Selection::to_expr`]), so HT is the ordinary
//! expression `sum(Jet_pt[Jet_pt > 30]) >= 200` and the trigger OR is
//! plain `||` — the bespoke structs became sugar.
//!
//! Two value *shapes* exist (checked at plan time against the file
//! schema): **event**-shaped expressions produce one value per event;
//! **object**-shaped expressions (anything referencing a jagged
//! branch outside an aggregation) produce one value per object of a
//! collection. Aggregations reduce object shape to event shape;
//! combining per-object values from *different* collections is an
//! error. Booleans are TCut-style numerics: nonzero is true,
//! comparisons yield `1.0`/`0.0`.
//!
//! Build expressions with the fluent API:
//!
//! ```
//! use skimroot::query::expr::Expr;
//!
//! // sum(Jet_pt[Jet_pt > 30]) >= 200  &&  (HLT_IsoMu24 || HLT_Ele27_WPTight)
//! let ht = Expr::sum_if(Expr::branch("Jet_pt"), Expr::branch("Jet_pt").gt(30.0)).ge(200.0);
//! let trig = Expr::branch("HLT_IsoMu24").or(Expr::branch("HLT_Ele27_WPTight"));
//! let cut = ht.and(trig);
//!
//! // Display renders the canonical cut-string form, which the
//! // `query::parse` frontend parses back to the identical AST.
//! let text = cut.to_string();
//! assert_eq!(skimroot::query::parse_cut(&text).unwrap(), cut);
//! assert_eq!(cut.branches(), vec!["Jet_pt", "HLT_IsoMu24", "HLT_Ele27_WPTight"]);
//! ```
//!
//! or parse them from a TCut-style string ([`crate::query::parse`]).

use std::fmt;

/// Unary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnaryOp {
    /// Arithmetic negation.
    Neg,
    /// Boolean not (`!x` — 1.0 if `x == 0`, else 0.0).
    Not,
    /// Absolute value (the `|eta| < 2.4` idiom).
    Abs,
}

/// Binary operators. `Min`/`Max` are the two-argument forms
/// (`min(a, b)`); the single-argument aggregations live in [`AggOp`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    /// `+`.
    Add,
    /// `-`.
    Sub,
    /// `*`.
    Mul,
    /// `/`.
    Div,
    /// `<`.
    Lt,
    /// `<=`.
    Le,
    /// `>`.
    Gt,
    /// `>=`.
    Ge,
    /// `==`.
    Eq,
    /// `!=`.
    Ne,
    /// `&&` (TCut numerics: nonzero is true).
    And,
    /// `||`.
    Or,
    /// Two-argument `min(a, b)`.
    Min,
    /// Two-argument `max(a, b)`.
    Max,
}

impl BinOp {
    /// Infix symbol (`Min`/`Max` render as calls, not infix).
    pub fn symbol(self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::Lt => "<",
            BinOp::Le => "<=",
            BinOp::Gt => ">",
            BinOp::Ge => ">=",
            BinOp::Eq => "==",
            BinOp::Ne => "!=",
            BinOp::And => "&&",
            BinOp::Or => "||",
            BinOp::Min => "min",
            BinOp::Max => "max",
        }
    }
}

/// Aggregations over a jagged (per-object) expression, reducing it to
/// one event-level value. Selection semantics cover the first `M`
/// object slots (the engine's padding capacity), matching the
/// object-group counting of the fixed-function kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggOp {
    /// Number of objects whose predicate holds.
    Count,
    /// Sum of the argument over (optionally predicate-selected) objects.
    Sum,
    /// 1.0 if any object satisfies the predicate.
    Any,
    /// 1.0 if every object satisfies the predicate (vacuously true).
    All,
    /// Maximum over selected objects (`-inf` if none).
    Max,
    /// Minimum over selected objects (`+inf` if none).
    Min,
}

impl AggOp {
    /// The cut-string spelling of the aggregation.
    pub fn name(self) -> &'static str {
        match self {
            AggOp::Count => "count",
            AggOp::Sum => "sum",
            AggOp::Any => "any",
            AggOp::All => "all",
            AggOp::Max => "max",
            AggOp::Min => "min",
        }
    }
}

/// A query expression: the open IR every frontend lowers to (fluent
/// builder, cut strings, the legacy JSON schema). See the module docs.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    /// Numeric literal (must be finite for the string form to round-trip).
    Num(f64),
    /// Branch reference; resolved against the file schema at plan time
    /// (scalar branches are event-shaped, jagged branches object-shaped).
    Branch(String),
    /// Unary application (`-x`, `!x`, `abs(x)`).
    Unary(UnaryOp, Box<Expr>),
    /// Binary application (arithmetic, comparison, boolean, min/max).
    Binary(BinOp, Box<Expr>, Box<Expr>),
    /// Aggregation: `op(arg)` or `op(arg[pred])`. For `Count`/`Any`/
    /// `All` the argument *is* the predicate.
    Agg {
        /// Which aggregation.
        op: AggOp,
        /// The per-object argument (the predicate for count/any/all).
        arg: Box<Expr>,
        /// Optional object-selection predicate (`arg[pred]`).
        pred: Option<Box<Expr>>,
    },
}

#[allow(clippy::should_implement_trait)]
impl Expr {
    /// Numeric literal.
    pub fn num(v: f64) -> Expr {
        Expr::Num(v)
    }

    /// Branch reference (also available via `Expr::from("name")`).
    pub fn branch(name: impl Into<String>) -> Expr {
        Expr::Branch(name.into())
    }

    fn bin(self, op: BinOp, rhs: impl Into<Expr>) -> Expr {
        Expr::Binary(op, Box::new(self), Box::new(rhs.into()))
    }

    // ---- comparisons -------------------------------------------------

    /// `self > rhs`.
    pub fn gt(self, rhs: impl Into<Expr>) -> Expr {
        self.bin(BinOp::Gt, rhs)
    }

    /// `self >= rhs`.
    pub fn ge(self, rhs: impl Into<Expr>) -> Expr {
        self.bin(BinOp::Ge, rhs)
    }

    /// `self < rhs`.
    pub fn lt(self, rhs: impl Into<Expr>) -> Expr {
        self.bin(BinOp::Lt, rhs)
    }

    /// `self <= rhs`.
    pub fn le(self, rhs: impl Into<Expr>) -> Expr {
        self.bin(BinOp::Le, rhs)
    }

    /// `self == rhs`.
    pub fn eq(self, rhs: impl Into<Expr>) -> Expr {
        self.bin(BinOp::Eq, rhs)
    }

    /// `self != rhs`.
    pub fn ne(self, rhs: impl Into<Expr>) -> Expr {
        self.bin(BinOp::Ne, rhs)
    }

    // ---- boolean structure -------------------------------------------

    /// `self && rhs` (nonzero is true).
    pub fn and(self, rhs: impl Into<Expr>) -> Expr {
        self.bin(BinOp::And, rhs)
    }

    /// `self || rhs`.
    pub fn or(self, rhs: impl Into<Expr>) -> Expr {
        self.bin(BinOp::Or, rhs)
    }

    // ---- functions ---------------------------------------------------

    /// `abs(self)` — the `|eta| < 2.4` idiom.
    pub fn abs(self) -> Expr {
        Expr::Unary(UnaryOp::Abs, Box::new(self))
    }

    /// Two-argument minimum `min(self, rhs)`.
    pub fn min(self, rhs: impl Into<Expr>) -> Expr {
        self.bin(BinOp::Min, rhs)
    }

    /// Two-argument maximum `max(self, rhs)`.
    pub fn max(self, rhs: impl Into<Expr>) -> Expr {
        self.bin(BinOp::Max, rhs)
    }

    // ---- aggregations ------------------------------------------------

    /// Low-level aggregation constructor; prefer the named helpers.
    pub fn agg(op: AggOp, arg: Expr, pred: Option<Expr>) -> Expr {
        Expr::Agg { op, arg: Box::new(arg), pred: pred.map(Box::new) }
    }

    /// `count(pred)` — objects satisfying the predicate.
    pub fn count(pred: impl Into<Expr>) -> Expr {
        Expr::agg(AggOp::Count, pred.into(), None)
    }

    /// `any(pred)` — 1.0 if at least one object satisfies the predicate.
    pub fn any(pred: impl Into<Expr>) -> Expr {
        Expr::agg(AggOp::Any, pred.into(), None)
    }

    /// `all(pred)` — 1.0 if every object satisfies the predicate.
    pub fn all(pred: impl Into<Expr>) -> Expr {
        Expr::agg(AggOp::All, pred.into(), None)
    }

    /// `sum(arg)` over all objects of the collection.
    pub fn sum(arg: impl Into<Expr>) -> Expr {
        Expr::agg(AggOp::Sum, arg.into(), None)
    }

    /// `sum(arg[pred])` — sum over objects passing the predicate (how
    /// HT is spelled: `sum(Jet_pt[Jet_pt > 30])`).
    pub fn sum_if(arg: impl Into<Expr>, pred: impl Into<Expr>) -> Expr {
        Expr::agg(AggOp::Sum, arg.into(), Some(pred.into()))
    }

    /// `max(arg)` over the collection (`-inf` when empty).
    pub fn max_of(arg: impl Into<Expr>) -> Expr {
        Expr::agg(AggOp::Max, arg.into(), None)
    }

    /// `min(arg)` over the collection (`+inf` when empty).
    pub fn min_of(arg: impl Into<Expr>) -> Expr {
        Expr::agg(AggOp::Min, arg.into(), None)
    }

    // ---- introspection -----------------------------------------------

    /// Branch names the expression reads, deduplicated, in first-use
    /// (depth-first, left-to-right) order — the §3.1 filtering-criteria
    /// derivation now walks this.
    pub fn branches(&self) -> Vec<String> {
        let mut out = Vec::new();
        self.walk_branches(&mut out);
        out
    }

    fn walk_branches(&self, out: &mut Vec<String>) {
        match self {
            Expr::Num(_) => {}
            Expr::Branch(name) => {
                if !out.iter().any(|n| n == name) {
                    out.push(name.clone());
                }
            }
            Expr::Unary(_, x) => x.walk_branches(out),
            Expr::Binary(_, a, b) => {
                a.walk_branches(out);
                b.walk_branches(out);
            }
            Expr::Agg { arg, pred, .. } => {
                arg.walk_branches(out);
                if let Some(p) = pred {
                    p.walk_branches(out);
                }
            }
        }
    }

    /// Multi-line indented rendering of the AST (the `--explain` view).
    pub fn tree_string(&self) -> String {
        let mut out = String::new();
        self.tree_fmt(&mut out, 0);
        out
    }

    fn tree_fmt(&self, out: &mut String, indent: usize) {
        for _ in 0..indent {
            out.push_str("  ");
        }
        match self {
            Expr::Num(v) => {
                out.push_str("num ");
                fmt_num(out, *v);
                out.push('\n');
            }
            Expr::Branch(name) => {
                out.push_str("branch ");
                out.push_str(name);
                out.push('\n');
            }
            Expr::Unary(op, x) => {
                let name = match op {
                    UnaryOp::Neg => "neg",
                    UnaryOp::Not => "not",
                    UnaryOp::Abs => "abs",
                };
                out.push_str(name);
                out.push('\n');
                x.tree_fmt(out, indent + 1);
            }
            Expr::Binary(op, a, b) => {
                out.push_str(op.symbol());
                out.push('\n');
                a.tree_fmt(out, indent + 1);
                b.tree_fmt(out, indent + 1);
            }
            Expr::Agg { op, arg, pred } => {
                out.push_str(op.name());
                if pred.is_some() {
                    out.push_str(" [filtered]");
                }
                out.push('\n');
                arg.tree_fmt(out, indent + 1);
                if let Some(p) = pred {
                    p.tree_fmt(out, indent + 1);
                }
            }
        }
    }
}

// ---- operator-overload sugar ----------------------------------------

impl<T: Into<Expr>> std::ops::Add<T> for Expr {
    type Output = Expr;
    fn add(self, rhs: T) -> Expr {
        self.bin(BinOp::Add, rhs)
    }
}

impl<T: Into<Expr>> std::ops::Sub<T> for Expr {
    type Output = Expr;
    fn sub(self, rhs: T) -> Expr {
        self.bin(BinOp::Sub, rhs)
    }
}

impl<T: Into<Expr>> std::ops::Mul<T> for Expr {
    type Output = Expr;
    fn mul(self, rhs: T) -> Expr {
        self.bin(BinOp::Mul, rhs)
    }
}

impl<T: Into<Expr>> std::ops::Div<T> for Expr {
    type Output = Expr;
    fn div(self, rhs: T) -> Expr {
        self.bin(BinOp::Div, rhs)
    }
}

impl std::ops::Neg for Expr {
    type Output = Expr;
    fn neg(self) -> Expr {
        // Fold literal negation so `-3.5` and the parse of "-3.5"
        // build the same node (Display/parse round-trip).
        match self {
            Expr::Num(v) => Expr::Num(-v),
            e => Expr::Unary(UnaryOp::Neg, Box::new(e)),
        }
    }
}

impl std::ops::Not for Expr {
    type Output = Expr;
    fn not(self) -> Expr {
        Expr::Unary(UnaryOp::Not, Box::new(self))
    }
}

impl From<f64> for Expr {
    fn from(v: f64) -> Expr {
        Expr::Num(v)
    }
}

impl From<i32> for Expr {
    fn from(v: i32) -> Expr {
        Expr::Num(v as f64)
    }
}

impl From<&str> for Expr {
    fn from(name: &str) -> Expr {
        Expr::Branch(name.to_string())
    }
}

impl From<String> for Expr {
    fn from(name: String) -> Expr {
        Expr::Branch(name)
    }
}

fn fmt_num(out: &mut String, v: f64) {
    use std::fmt::Write as _;
    if v.fract() == 0.0 && v.abs() < 1e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v}");
    }
}

/// Canonical cut-string form: fully parenthesized so the parse of the
/// rendering is always the identical AST (property-tested).
impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Num(v) => {
                let mut s = String::new();
                fmt_num(&mut s, *v);
                f.write_str(&s)
            }
            Expr::Branch(name) => f.write_str(name),
            Expr::Unary(UnaryOp::Neg, x) => write!(f, "(-{x})"),
            Expr::Unary(UnaryOp::Not, x) => write!(f, "!({x})"),
            Expr::Unary(UnaryOp::Abs, x) => write!(f, "abs({x})"),
            Expr::Binary(op @ (BinOp::Min | BinOp::Max), a, b) => {
                write!(f, "{}({a}, {b})", op.symbol())
            }
            Expr::Binary(op, a, b) => write!(f, "({a} {} {b})", op.symbol()),
            Expr::Agg { op, arg, pred: None } => write!(f, "{}({arg})", op.name()),
            Expr::Agg { op, arg, pred: Some(p) } => write!(f, "{}({arg}[{p}])", op.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_constructs_expected_ast() {
        let e = Expr::branch("nElectron").ge(1);
        assert_eq!(
            e,
            Expr::Binary(
                BinOp::Ge,
                Box::new(Expr::Branch("nElectron".into())),
                Box::new(Expr::Num(1.0)),
            )
        );
        let ht = Expr::sum_if(Expr::branch("Jet_pt"), Expr::branch("Jet_pt").gt(30.0)).ge(200.0);
        match &ht {
            Expr::Binary(BinOp::Ge, lhs, _) => match lhs.as_ref() {
                Expr::Agg { op: AggOp::Sum, pred: Some(_), .. } => {}
                other => panic!("unexpected lhs: {other:?}"),
            },
            other => panic!("unexpected: {other:?}"),
        }
    }

    #[test]
    fn display_is_canonical() {
        let e = Expr::branch("a").gt(25.0).and(Expr::branch("b").abs().lt(2.4));
        assert_eq!(e.to_string(), "((a > 25) && (abs(b) < 2.4))");
        let e = Expr::count(Expr::branch("Jet_pt").gt(30.0)).ge(2);
        assert_eq!(e.to_string(), "(count((Jet_pt > 30)) >= 2)");
        let e = Expr::sum_if(Expr::branch("j"), Expr::branch("j").gt(30.0));
        assert_eq!(e.to_string(), "sum(j[(j > 30)])");
        let e = Expr::branch("x").min(Expr::branch("y"));
        assert_eq!(e.to_string(), "min(x, y)");
        let e = Expr::max_of(Expr::branch("Muon_pt"));
        assert_eq!(e.to_string(), "max(Muon_pt)");
        let e = -(Expr::branch("x") + 1.0);
        assert_eq!(e.to_string(), "(-(x + 1))");
        let e = !Expr::branch("flag");
        assert_eq!(e.to_string(), "!(flag)");
        assert_eq!((-Expr::num(3.5)).to_string(), "-3.5");
    }

    #[test]
    fn branches_deduplicate_in_order() {
        let e = Expr::sum_if(Expr::branch("Jet_pt"), Expr::branch("Jet_pt").gt(30.0))
            .ge(200.0)
            .and(Expr::branch("MET_pt").gt(100.0))
            .or(Expr::any(Expr::branch("Jet_pt").gt(0.0)));
        assert_eq!(e.branches(), vec!["Jet_pt", "MET_pt"]);
    }

    #[test]
    fn tree_rendering_indents() {
        let e = Expr::branch("a").gt(1.0).and(Expr::branch("b"));
        let t = e.tree_string();
        assert!(t.starts_with("&&\n"));
        assert!(t.contains("  >\n"));
        assert!(t.contains("    branch a\n"));
        assert!(t.contains("  branch b\n"));
    }

    #[test]
    fn neg_folds_literals_only() {
        assert_eq!(-Expr::num(2.0), Expr::Num(-2.0));
        assert_eq!(
            -Expr::branch("x"),
            Expr::Unary(UnaryOp::Neg, Box::new(Expr::Branch("x".into())))
        );
    }
}
