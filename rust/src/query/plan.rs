//! Query planning: branch categorization + cut-program compilation
//! (§3.1–3.2).
//!
//! Given a parsed [`SkimQuery`] and the file schema, the planner:
//!
//! 1. expands the output branch patterns (curated `HLT_*` mapping
//!    included) → the branches written to the filtered file;
//! 2. splits branches into **filtering criteria** (read in phase 1,
//!    O(10) in NanoAOD practice) and **output-only** (read in phase 2,
//!    only for passing events, O(100)) — the two-phase split that
//!    removes most data movement;
//! 3. compiles the selection into a numeric [`CutProgram`]: flat column
//!    lists + opcode/threshold banks consumed identically by the Rust
//!    scalar interpreter and the AOT Pallas kernel (which has fixed
//!    capacity; programs exceeding it fall back to the interpreter).
//!
//! The open IR ([`Expr`]) compiles through the same funnel: top-level
//! conjuncts of the query's `cut` are **classified** into the kernel's
//! fixed-function stages where they match (simple scalar comparisons →
//! preselection bank, `count(simple-cuts) >= k` → object groups,
//! `sum(jet[jet > t]) >= h` → the HT unit, OR-of-flags → the trigger
//! bank), so a cut string that *is* expressible in the legacy schema
//! still rides the vectorized PJRT path. Anything else compiles to a
//! residual [`CExpr`] evaluated by the interpreter —
//! [`CutProgram::fits_kernel`] stays the honest gate.

use super::ast::SkimQuery;
use super::expr::{AggOp, BinOp, Expr, UnaryOp};
use super::wildcard;
use crate::troot::{BranchKind, DType, FileMeta};
use crate::{Error, Result};
use std::sync::Arc;

/// A dense, plan-time branch index: position of the branch in
/// [`SkimPlan::criteria_branches`] (and therefore in the engine's
/// phase-1 fetch order). The engine's per-cluster basket stores are
/// plain `Vec`s indexed by `BranchId` — resolving names to ids once at
/// plan time removes every per-basket string hash/clone from the hot
/// path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BranchId(pub u32);

impl BranchId {
    /// The `Vec` index this id addresses.
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// Kernel capacity (must match `python/compile/kernels/skim.py`):
/// maximum jagged columns.
pub const KERNEL_MAX_OBJ_COLS: usize = 12;
/// Kernel capacity: maximum scalar columns.
pub const KERNEL_MAX_SCALAR_COLS: usize = 16;
/// Kernel capacity: maximum per-object cuts across all groups.
pub const KERNEL_MAX_OBJ_CUTS: usize = 12;
/// Kernel capacity: maximum preselection scalar cuts.
pub const KERNEL_MAX_SCALAR_CUTS: usize = 6;
/// Kernel capacity: maximum object groups.
pub const KERNEL_MAX_GROUPS: usize = 4;

/// One compiled per-object cut: `col` indexes [`CutProgram::obj_columns`].
#[derive(Debug, Clone, PartialEq)]
pub struct ObjCutParam {
    /// Index into [`CutProgram::obj_columns`].
    pub col: usize,
    /// 0 `>` · 1 `>=` · 2 `<` · 3 `<=` · 4 `==` · 5 `!=`
    pub op: u8,
    /// Compare `|x|` instead of `x`.
    pub abs: bool,
    /// Threshold.
    pub value: f32,
}

/// One compiled scalar cut: `col` indexes [`CutProgram::scalar_columns`].
#[derive(Debug, Clone, PartialEq)]
pub struct ScalarCutParam {
    /// Index into [`CutProgram::scalar_columns`].
    pub col: usize,
    /// Comparison opcode (same coding as [`ObjCutParam::op`]).
    pub op: u8,
    /// Compare `|x|` instead of `x`.
    pub abs: bool,
    /// Threshold.
    pub value: f32,
}

/// A collection's object-level requirement: at least `min_count`
/// objects passing all cuts in `cut_range` (indices into
/// [`CutProgram::obj_cuts`]). All of a group's cut columns share the
/// same collection, hence the same multiplicity.
#[derive(Debug, Clone, PartialEq)]
pub struct ObjGroup {
    /// Collection prefix (for multiplicity lookup and reports).
    pub collection: String,
    /// Indices into [`CutProgram::obj_cuts`] this group requires.
    pub cut_range: std::ops::Range<usize>,
    /// Minimum surviving objects.
    pub min_count: u32,
}

/// Compiled HT requirement.
#[derive(Debug, Clone, PartialEq)]
pub struct HtParam {
    /// Index into `obj_columns` of the jet-pT column.
    pub col: usize,
    /// Per-object pT threshold for inclusion in the sum.
    pub object_pt_min: f32,
    /// Minimum HT for the event to pass.
    pub min_ht: f32,
}

/// One zone-map comparison: "some value of `branch` in the basket
/// could satisfy `cmp(x, op, value)`" (with `|x|` when `abs`). The
/// branch name is kept (not a [`BranchId`]) because zone maps are
/// keyed by the *file's* schema, not the plan's criteria order.
#[derive(Debug, Clone, PartialEq)]
pub struct ZoneCmp {
    /// Branch whose basket summary is consulted.
    pub branch: String,
    /// Comparison opcode (same coding as [`ObjCutParam::op`]).
    pub op: u8,
    /// Compare `|x|` instead of `x`.
    pub abs: bool,
    /// Threshold.
    pub value: f32,
}

/// A necessary condition for *any* event of a cluster to pass the
/// selection, evaluable against a [`crate::index::FileIndex`] without
/// touching data. Each predicate is implied by one top-level conjunct
/// of the compiled program, so a cluster where any predicate is
/// **dead** (provably unsatisfiable) can be skipped entirely:
///
/// * a scalar preselection cut needs some scalar value in the basket
///   satisfying it;
/// * an object group with `min_count >= 1` needs, for each of its
///   cuts, at least one object value satisfying that cut;
/// * an HT requirement with `min_ht > 0` needs at least one jet above
///   `object_pt_min` (an empty sum is 0);
/// * a trigger OR needs some flag value `> 0.5` for some flag.
///
/// Residual IR expressions never produce predicates (they are extra
/// ANDed conjuncts — ignoring them is conservative), and `min_count =
/// 0` groups are vacuously satisfiable. Missing branches or baskets in
/// the index always count as satisfiable, so pruning can only ever
/// skip clusters the full scan would also reject.
#[derive(Debug, Clone, PartialEq)]
pub enum ZonePredicate {
    /// A single necessary comparison.
    Cmp(ZoneCmp),
    /// A disjunction (the trigger OR): dead only when *every* arm is.
    Or(Vec<ZoneCmp>),
}

impl ZonePredicate {
    /// Is this predicate provably unsatisfiable for cluster `basket`
    /// according to `index`? (Basket index == cluster index: the
    /// writer emits one basket per branch per cluster.)
    pub fn dead(&self, index: &crate::index::FileIndex, basket: usize) -> bool {
        let live =
            |c: &ZoneCmp| index.may_match(&c.branch, basket, c.op, c.abs, c.value);
        match self {
            ZonePredicate::Cmp(c) => !live(c),
            ZonePredicate::Or(cs) => !cs.is_empty() && cs.iter().all(|c| !live(c)),
        }
    }
}

/// A compiled IR expression: [`Expr`] with branch references resolved
/// to column indices of the owning [`CutProgram`]. Shape-checked at
/// compile time: jagged column references only occur inside an `Agg`.
/// Only the scalar interpreter evaluates these (the AOT kernel's
/// fixed-function stages cannot).
#[derive(Debug, Clone, PartialEq)]
pub enum CExpr {
    /// Numeric literal.
    Num(f32),
    /// Index into [`CutProgram::scalar_columns`].
    Scalar(usize),
    /// Index into [`CutProgram::obj_columns`].
    Jagged(usize),
    /// Unary application.
    Unary(UnaryOp, Box<CExpr>),
    /// Binary application.
    Binary(BinOp, Box<CExpr>, Box<CExpr>),
    /// Aggregation over object slots. `nobj` is the obj-column index
    /// whose per-event multiplicity bounds the valid slots (the first
    /// jagged column the aggregation references).
    Agg {
        /// Which aggregation.
        op: AggOp,
        /// Obj-column index bounding the valid slots.
        nobj: usize,
        /// The per-object argument.
        arg: Box<CExpr>,
        /// Optional object-selection predicate.
        pred: Option<Box<CExpr>>,
    },
    /// A common subexpression hoisted by the CSE pass: every
    /// occurrence of a structurally-equal subtree points at one shared
    /// node, so batch evaluators compute it once per batch (a scratch
    /// column keyed by the node's address) and reuse the values at
    /// every other occurrence. Value semantics are transparent — the
    /// scalar oracle simply recurses through it — so masks are
    /// bit-identical with and without the pass. (Derived `PartialEq`
    /// compares pointees, keeping program equality structural.)
    Shared(Arc<CExpr>),
}

/// The numeric, engine-agnostic form of a selection.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CutProgram {
    /// Jagged f32 columns the program reads (order = kernel column ids).
    pub obj_columns: Vec<String>,
    /// Scalar columns (f32-convertible) the program reads.
    pub scalar_columns: Vec<String>,
    /// Per-object cuts, grouped by [`CutProgram::groups`].
    pub obj_cuts: Vec<ObjCutParam>,
    /// Object-level requirements over `obj_cuts` ranges.
    pub groups: Vec<ObjGroup>,
    /// Preselection scalar cuts (ANDed).
    pub scalar_cuts: Vec<ScalarCutParam>,
    /// Optional HT requirement.
    pub ht: Option<HtParam>,
    /// Indices into `scalar_columns` of trigger flags (ORed; empty =
    /// no trigger requirement).
    pub triggers: Vec<usize>,
    /// Residual IR expressions (event-level booleans, ANDed) beyond
    /// the kernel's fixed-function stages. Interpreter-only.
    pub exprs: Vec<CExpr>,
}

impl CutProgram {
    /// Does this program fit the AOT kernel's fixed capacity? Honest
    /// gate for the vectorized PJRT path: any residual IR expression
    /// disqualifies it (the kernel has no general-expression unit).
    pub fn fits_kernel(&self) -> bool {
        self.kernel_unfit_reasons().is_empty()
    }

    /// Why the vectorized path is unavailable (empty = it fits). Each
    /// entry is one exceeded capacity or unsupported construct.
    pub fn kernel_unfit_reasons(&self) -> Vec<String> {
        let mut out = Vec::new();
        if self.obj_columns.len() > KERNEL_MAX_OBJ_COLS {
            out.push(format!(
                "{} jagged columns exceed the kernel's {KERNEL_MAX_OBJ_COLS}",
                self.obj_columns.len()
            ));
        }
        if self.scalar_columns.len() > KERNEL_MAX_SCALAR_COLS {
            out.push(format!(
                "{} scalar columns exceed the kernel's {KERNEL_MAX_SCALAR_COLS}",
                self.scalar_columns.len()
            ));
        }
        if self.obj_cuts.len() > KERNEL_MAX_OBJ_CUTS {
            out.push(format!(
                "{} object cuts exceed the kernel's {KERNEL_MAX_OBJ_CUTS}",
                self.obj_cuts.len()
            ));
        }
        if self.scalar_cuts.len() > KERNEL_MAX_SCALAR_CUTS {
            out.push(format!(
                "{} scalar cuts exceed the kernel's {KERNEL_MAX_SCALAR_CUTS}",
                self.scalar_cuts.len()
            ));
        }
        if self.groups.len() > KERNEL_MAX_GROUPS {
            out.push(format!(
                "{} object groups exceed the kernel's {KERNEL_MAX_GROUPS}",
                self.groups.len()
            ));
        }
        if !self.exprs.is_empty() {
            out.push(format!(
                "{} residual IR expression(s) have no fixed-function kernel stage",
                self.exprs.len()
            ));
        }
        out
    }

    /// No cuts at all: every event passes (copy-all).
    pub fn is_trivial(&self) -> bool {
        self.scalar_cuts.is_empty()
            && self.groups.is_empty()
            && self.ht.is_none()
            && self.triggers.is_empty()
            && self.exprs.is_empty()
    }

    fn obj_col(&mut self, name: &str) -> usize {
        match self.obj_columns.iter().position(|c| c == name) {
            Some(i) => i,
            None => {
                self.obj_columns.push(name.to_string());
                self.obj_columns.len() - 1
            }
        }
    }

    fn scalar_col(&mut self, name: &str) -> usize {
        match self.scalar_columns.iter().position(|c| c == name) {
            Some(i) => i,
            None => {
                self.scalar_columns.push(name.to_string());
                self.scalar_columns.len() - 1
            }
        }
    }
}

/// The full execution plan for one skim job.
#[derive(Debug, Clone)]
pub struct SkimPlan {
    /// Branches written to the output file (schema order).
    pub output_branches: Vec<String>,
    /// Branches read in phase 1 to evaluate the selection.
    pub criteria_branches: Vec<String>,
    /// Output branches *not* needed for filtering — fetched in phase 2,
    /// only for events that passed.
    pub output_only_branches: Vec<String>,
    /// The compiled numeric cut program.
    pub program: CutProgram,
    /// Interned source of each program jagged column:
    /// `obj_col_branch[c]` is the [`BranchId`] (index into
    /// `criteria_branches`) holding the basket that fills
    /// `program.obj_columns[c]`.
    pub obj_col_branch: Vec<BranchId>,
    /// Interned source of each program scalar column (see
    /// [`SkimPlan::obj_col_branch`]).
    pub scalar_col_branch: Vec<BranchId>,
    /// Necessary per-cluster conditions compiled from the program's
    /// conjuncts, for zone-map basket pruning (empty for trivial
    /// programs — nothing to prune against).
    pub zone_predicates: Vec<ZonePredicate>,
    /// Planner warnings (unmatched patterns, curated-set fallbacks).
    pub warnings: Vec<String>,
}

impl SkimPlan {
    /// Build a plan: expand patterns, validate branches against the
    /// schema, compile the cut program.
    pub fn build(query: &SkimQuery, meta: &FileMeta) -> Result<SkimPlan> {
        let schema: Vec<&str> = meta.branch_names().collect();
        let expansion = wildcard::expand(&query.branches, &schema, query.force_all);
        let mut warnings = expansion.warnings;
        if expansion.selected.is_empty() {
            return Err(Error::query("no output branches selected"));
        }

        // --- validate + compile the structured selection ---------------
        let mut program = CutProgram::default();

        let require = |name: &str, kind: BranchKind| -> Result<DType> {
            let b = meta
                .branch(name)
                .ok_or_else(|| Error::query(format!("selection references unknown branch '{name}'")))?;
            if b.desc.kind != kind {
                return Err(Error::query(format!(
                    "branch '{name}' is {:?}, expected {:?}",
                    b.desc.kind, kind
                )));
            }
            Ok(b.desc.dtype)
        };

        for cut in &query.selection.preselection {
            require(&cut.branch, BranchKind::Scalar)?;
            let col = program.scalar_col(&cut.branch);
            let (op, abs) = cut.op.code();
            program.scalar_cuts.push(ScalarCutParam { col, op, abs, value: cut.value as f32 });
        }

        for sel in &query.selection.objects {
            let start = program.obj_cuts.len();
            for cut in &sel.cuts {
                let dtype = require(&cut.var, BranchKind::Jagged)?;
                if dtype != DType::F32 {
                    return Err(Error::query(format!(
                        "object cut variable '{}' must be f32 (got {})",
                        cut.var,
                        dtype.name()
                    )));
                }
                let col = program.obj_col(&cut.var);
                let (op, abs) = cut.op.code();
                program.obj_cuts.push(ObjCutParam { col, op, abs, value: cut.value as f32 });
            }
            program.groups.push(ObjGroup {
                collection: sel.collection.clone(),
                cut_range: start..program.obj_cuts.len(),
                min_count: sel.min_count,
            });
        }

        if let Some(ht) = &query.selection.event.ht {
            let dtype = require(&ht.jet_pt, BranchKind::Jagged)?;
            if dtype != DType::F32 {
                return Err(Error::query("HT jet_pt branch must be f32"));
            }
            let col = program.obj_col(&ht.jet_pt);
            program.ht = Some(HtParam {
                col,
                object_pt_min: ht.object_pt_min as f32,
                min_ht: ht.min as f32,
            });
        }

        for trig in &query.selection.event.triggers_any {
            require(trig, BranchKind::Scalar)?;
            let col = program.scalar_col(trig);
            program.triggers.push(col);
        }

        // --- compile the free-form IR cut ------------------------------
        if let Some(cut) = &query.cut {
            compile_cut(&mut program, cut, meta)?;
        }

        // --- common-subexpression elimination over residual IR ---------
        // Hoist structurally-equal subtrees (within and across residual
        // conjuncts) into shared evaluate-once nodes. Purely an
        // evaluation-cost rewrite: expression count, conjunct identity
        // and values are unchanged.
        cse_exprs(&mut program);

        // --- two-phase branch split ------------------------------------
        let criteria = query.referenced_branches();
        for c in &criteria {
            // Criteria branches must exist even if not in the output.
            if meta.branch(c).is_none() {
                return Err(Error::query(format!("criteria branch '{c}' not in file")));
            }
        }
        let output_only: Vec<String> = expansion
            .selected
            .iter()
            .filter(|b| !criteria.contains(b))
            .cloned()
            .collect();

        // --- branch interning ------------------------------------------
        // Every program column reads a criteria branch (the program was
        // compiled from the same expressions `referenced_branches`
        // walks); resolve each column's source to its dense BranchId
        // once, here, so the engine never hashes a branch name per
        // basket again.
        let intern = |name: &str| -> Result<BranchId> {
            criteria
                .iter()
                .position(|c| c.as_str() == name)
                .map(|i| BranchId(i as u32))
                .ok_or_else(|| {
                    Error::query(format!(
                        "internal: program column '{name}' missing from criteria set"
                    ))
                })
        };
        let obj_col_branch: Vec<BranchId> = program
            .obj_columns
            .iter()
            .map(|n| intern(n))
            .collect::<Result<_>>()?;
        let scalar_col_branch: Vec<BranchId> = program
            .scalar_columns
            .iter()
            .map(|n| intern(n))
            .collect::<Result<_>>()?;

        let unfit = program.kernel_unfit_reasons();
        if !unfit.is_empty() {
            warnings.push(format!(
                "cut program exceeds AOT kernel capacity ({}): \
                 vectorized path unavailable, scalar interpreter will be used",
                unfit.join("; ")
            ));
        }

        let zone_predicates = compile_zone_predicates(&program);
        Ok(SkimPlan {
            output_branches: expansion.selected,
            criteria_branches: criteria,
            output_only_branches: output_only,
            program,
            obj_col_branch,
            scalar_col_branch,
            zone_predicates,
            warnings,
        })
    }

    /// Human-readable rendering of the plan: the selection expression
    /// tree, the phase-1/phase-2 branch fetch sets, the compiled
    /// program summary and the kernel-fit decision (with reasons).
    /// This is what `skimroot skim --explain` prints.
    pub fn explain(&self, query: &SkimQuery) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "skim plan: '{}' -> '{}'", query.input, query.output);
        out.push_str("\nselection expression:\n");
        match query.combined_cut() {
            Some(expr) => {
                for line in expr.tree_string().lines() {
                    let _ = writeln!(out, "  {line}");
                }
            }
            None => out.push_str("  (none — every event passes, copy-all)\n"),
        }
        out.push_str("\nbranch fetch plan:\n");
        let _ = writeln!(out, "  output branches:        {}", self.output_branches.len());
        let _ = writeln!(
            out,
            "  phase 1 (criteria):     {} -> [{}]",
            self.criteria_branches.len(),
            self.criteria_branches.join(", ")
        );
        let _ = writeln!(
            out,
            "  phase 2 (output-only):  {} (fetched only for passing clusters)",
            self.output_only_branches.len()
        );
        let p = &self.program;
        out.push_str("\ncompiled cut program:\n");
        let _ = writeln!(
            out,
            "  scalar cuts:   {}    object groups: {} ({} per-object cuts)",
            p.scalar_cuts.len(),
            p.groups.len(),
            p.obj_cuts.len()
        );
        match &p.ht {
            Some(ht) => {
                let _ = writeln!(
                    out,
                    "  ht unit:       sum({col}[{col} > {pt}]) >= {min}",
                    col = p.obj_columns[ht.col],
                    pt = ht.object_pt_min,
                    min = ht.min_ht
                );
            }
            None => out.push_str("  ht unit:       (unused)\n"),
        }
        let _ = writeln!(out, "  trigger OR:    {} flag(s)", p.triggers.len());
        let _ = writeln!(out, "  residual IR:   {} expression(s)", p.exprs.len());
        let _ = writeln!(
            out,
            "  zone preds:    {} (basket pruning when a .tridx sidecar is present)",
            self.zone_predicates.len()
        );
        out.push_str("\nevaluation path: ");
        let unfit = p.kernel_unfit_reasons();
        if unfit.is_empty() {
            out.push_str(
                "vectorized AOT kernel (program fits capacity; \
                 requires loaded PJRT artifacts, else interpreter)\n",
            );
        } else {
            out.push_str("scalar interpreter — kernel fallback because:\n");
            for r in &unfit {
                let _ = writeln!(out, "  - {r}");
            }
        }
        if !self.warnings.is_empty() {
            out.push_str("\nwarnings:\n");
            for w in &self.warnings {
                let _ = writeln!(out, "  - {w}");
            }
        }
        out
    }
}

/// Derive the zone predicates a compiled program licenses (see
/// [`ZonePredicate`] for the per-conjunct soundness argument).
fn compile_zone_predicates(program: &CutProgram) -> Vec<ZonePredicate> {
    let mut preds = Vec::new();
    for c in &program.scalar_cuts {
        preds.push(ZonePredicate::Cmp(ZoneCmp {
            branch: program.scalar_columns[c.col].clone(),
            op: c.op,
            abs: c.abs,
            value: c.value,
        }));
    }
    for g in &program.groups {
        if g.min_count == 0 {
            // "At least zero objects" holds vacuously; nothing to prune.
            continue;
        }
        for c in &program.obj_cuts[g.cut_range.clone()] {
            preds.push(ZonePredicate::Cmp(ZoneCmp {
                branch: program.obj_columns[c.col].clone(),
                op: c.op,
                abs: c.abs,
                value: c.value,
            }));
        }
    }
    if let Some(ht) = &program.ht {
        if ht.min_ht > 0.0 {
            preds.push(ZonePredicate::Cmp(ZoneCmp {
                branch: program.obj_columns[ht.col].clone(),
                op: 0,
                abs: false,
                value: ht.object_pt_min,
            }));
        }
    }
    if !program.triggers.is_empty() {
        preds.push(ZonePredicate::Or(
            program
                .triggers
                .iter()
                .map(|&s| ZoneCmp {
                    branch: program.scalar_columns[s].clone(),
                    op: 0,
                    abs: false,
                    value: 0.5,
                })
                .collect(),
        ));
    }
    preds
}

// ---- IR compilation -------------------------------------------------

/// Value shape of an expression: one value per event, or one value per
/// object of a jagged collection.
#[derive(Debug, Clone, PartialEq)]
enum Shape {
    Event,
    Object(String),
}

fn combine_shapes(a: Shape, b: Shape) -> Result<Shape> {
    match (a, b) {
        (Shape::Event, s) | (s, Shape::Event) => Ok(s),
        (Shape::Object(c1), Shape::Object(c2)) => {
            if c1 == c2 {
                Ok(Shape::Object(c1))
            } else {
                Err(Error::query(format!(
                    "cut combines per-object values from different collections \
                     ('{c1}' and '{c2}') in one expression"
                )))
            }
        }
    }
}

/// Resolve the shape of `e` against the file schema, validating branch
/// existence, aggregation operands and collection consistency.
fn shape_of(e: &Expr, meta: &FileMeta) -> Result<Shape> {
    match e {
        Expr::Num(_) => Ok(Shape::Event),
        Expr::Branch(name) => {
            let b = meta
                .branch(name)
                .ok_or_else(|| Error::query(format!("cut references unknown branch '{name}'")))?;
            match b.desc.kind {
                BranchKind::Scalar => Ok(Shape::Event),
                BranchKind::Jagged => Ok(Shape::Object(b.desc.group.clone())),
            }
        }
        Expr::Unary(_, x) => shape_of(x, meta),
        Expr::Binary(_, a, b) => combine_shapes(shape_of(a, meta)?, shape_of(b, meta)?),
        Expr::Agg { op, arg, pred } => {
            let mut s = shape_of(arg, meta)?;
            if let Some(p) = pred {
                s = combine_shapes(s, shape_of(p, meta)?)?;
            }
            match s {
                Shape::Object(_) => Ok(Shape::Event),
                Shape::Event => Err(Error::query(format!(
                    "aggregation '{}' requires a per-object (jagged) operand",
                    op.name()
                ))),
            }
        }
    }
}

/// Split a top-level AND tree into its conjuncts, left-to-right.
fn conjuncts(e: &Expr) -> Vec<&Expr> {
    match e {
        Expr::Binary(BinOp::And, a, b) => {
            let mut v = conjuncts(a);
            v.extend(conjuncts(b));
            v
        }
        _ => vec![e],
    }
}

/// Split an OR tree into its disjuncts, left-to-right.
fn disjuncts(e: &Expr) -> Vec<&Expr> {
    match e {
        Expr::Binary(BinOp::Or, a, b) => {
            let mut v = disjuncts(a);
            v.extend(disjuncts(b));
            v
        }
        _ => vec![e],
    }
}

fn cmp_code(op: BinOp) -> Option<u8> {
    match op {
        BinOp::Gt => Some(0),
        BinOp::Ge => Some(1),
        BinOp::Lt => Some(2),
        BinOp::Le => Some(3),
        BinOp::Eq => Some(4),
        BinOp::Ne => Some(5),
        _ => None,
    }
}

/// Match `branch OP literal` / `abs(branch) OP literal` →
/// `(name, opcode, abs, value)`.
fn as_simple_cmp(e: &Expr) -> Option<(&str, u8, bool, f64)> {
    let Expr::Binary(op, lhs, rhs) = e else { return None };
    let code = cmp_code(*op)?;
    let Expr::Num(v) = rhs.as_ref() else { return None };
    match lhs.as_ref() {
        Expr::Branch(n) => Some((n.as_str(), code, false, *v)),
        Expr::Unary(UnaryOp::Abs, inner) => match inner.as_ref() {
            Expr::Branch(n) => Some((n.as_str(), code, true, *v)),
            _ => None,
        },
        _ => None,
    }
}

/// Compile the query's free-form cut into `program`: classify each
/// top-level conjunct into the kernel's fixed-function stages where it
/// matches, otherwise compile it to a residual [`CExpr`]. An
/// object-shaped conjunct (e.g. a bare `Muon_pt > 30`) gets the TCut
/// implicit-`any` treatment — the event passes if any object satisfies
/// it — applied per conjunct (`A && obj` ≡ `A && any(obj)`, including
/// the zero-object case), so event-level conjuncts keep their kernel
/// classification.
fn compile_cut(program: &mut CutProgram, expr: &Expr, meta: &FileMeta) -> Result<()> {
    for term in conjuncts(expr) {
        let wrapped;
        let term = match shape_of(term, meta)? {
            Shape::Event => term,
            Shape::Object(_) => {
                wrapped = Expr::any(term.clone());
                &wrapped
            }
        };
        if try_scalar_cut(program, term, meta)
            || try_group(program, term, meta)
            || try_any_group(program, term, meta)
            || try_ht(program, term, meta)
            || try_triggers(program, term, meta)
        {
            continue;
        }
        let compiled = compile_expr(program, term, meta)?;
        program.exprs.push(compiled);
    }
    Ok(())
}

/// Conjunct classifier: simple scalar comparison → preselection bank.
fn try_scalar_cut(program: &mut CutProgram, term: &Expr, meta: &FileMeta) -> bool {
    let Some((name, op, abs, value)) = as_simple_cmp(term) else { return false };
    let Some(b) = meta.branch(name) else { return false };
    if b.desc.kind != BranchKind::Scalar {
        return false;
    }
    let col = program.scalar_col(name);
    program.scalar_cuts.push(ScalarCutParam { col, op, abs, value: value as f32 });
    true
}

/// Shared body of the group classifiers: if `pred` is a conjunction of
/// simple cuts over f32 jagged branches of one collection, compile it
/// as an [`ObjGroup`] with the given `min_count` and return true.
fn compile_group(
    program: &mut CutProgram,
    pred: &Expr,
    meta: &FileMeta,
    min_count: u32,
) -> bool {
    let mut cuts: Vec<(String, u8, bool, f64)> = Vec::new();
    let mut collection: Option<String> = None;
    for c in conjuncts(pred) {
        let Some((name, op, abs, value)) = as_simple_cmp(c) else { return false };
        let Some(b) = meta.branch(name) else { return false };
        if b.desc.kind != BranchKind::Jagged || b.desc.dtype != DType::F32 {
            return false;
        }
        match &collection {
            None => collection = Some(b.desc.group.clone()),
            Some(c0) if *c0 == b.desc.group => {}
            Some(_) => return false,
        }
        cuts.push((name.to_string(), op, abs, value));
    }
    let Some(collection) = collection else { return false };
    let start = program.obj_cuts.len();
    for (name, op, abs, value) in cuts {
        let col = program.obj_col(&name);
        program.obj_cuts.push(ObjCutParam { col, op, abs, value: value as f32 });
    }
    program.groups.push(ObjGroup {
        collection,
        cut_range: start..program.obj_cuts.len(),
        min_count,
    });
    true
}

/// Conjunct classifier: `count(simple-cuts over one collection) >= k`
/// → object group.
fn try_group(program: &mut CutProgram, term: &Expr, meta: &FileMeta) -> bool {
    let Expr::Binary(BinOp::Ge, lhs, rhs) = term else { return false };
    let Expr::Num(k) = rhs.as_ref() else { return false };
    if *k < 0.0 || k.fract() != 0.0 || *k > u32::MAX as f64 {
        return false;
    }
    let Expr::Agg { op: AggOp::Count, arg, pred: None } = lhs.as_ref() else {
        return false;
    };
    compile_group(program, arg, meta, *k as u32)
}

/// Conjunct classifier: bare `any(simple-cuts)` → object group with
/// `min_count` 1 (`any(p)` ≡ `count(p) >= 1`), so implicit-`any`
/// wrapped object cuts stay on the kernel path.
fn try_any_group(program: &mut CutProgram, term: &Expr, meta: &FileMeta) -> bool {
    let Expr::Agg { op: AggOp::Any, arg, pred: None } = term else { return false };
    compile_group(program, arg, meta, 1)
}

/// Conjunct classifier: `sum(jet[jet > t]) >= h` → the HT unit (one
/// per program, matching the kernel).
fn try_ht(program: &mut CutProgram, term: &Expr, meta: &FileMeta) -> bool {
    if program.ht.is_some() {
        return false;
    }
    let Expr::Binary(BinOp::Ge, lhs, rhs) = term else { return false };
    let Expr::Num(h) = rhs.as_ref() else { return false };
    let Expr::Agg { op: AggOp::Sum, arg, pred: Some(p) } = lhs.as_ref() else {
        return false;
    };
    let Expr::Branch(jet) = arg.as_ref() else { return false };
    let Expr::Binary(BinOp::Gt, pl, pr) = p.as_ref() else { return false };
    let (Expr::Branch(jet2), Expr::Num(t)) = (pl.as_ref(), pr.as_ref()) else {
        return false;
    };
    if jet != jet2 {
        return false;
    }
    let Some(b) = meta.branch(jet) else { return false };
    if b.desc.kind != BranchKind::Jagged || b.desc.dtype != DType::F32 {
        return false;
    }
    let col = program.obj_col(jet);
    program.ht = Some(HtParam { col, object_pt_min: *t as f32, min_ht: *h as f32 });
    true
}

/// Conjunct classifier: OR of bare scalar flags → the trigger bank
/// (one per program). Acceptance mirrors the legacy `triggers_any`
/// compilation exactly (any scalar dtype), so every lowered legacy
/// query classifies back to the identical program. Note the bank's
/// `> 0.5` test — identical to nonzero truthiness for 0/1 flag
/// branches, which is what trigger bits are; spell out `x != 0` in a
/// cut string if a non-flag scalar needs exact nonzero semantics.
fn try_triggers(program: &mut CutProgram, term: &Expr, meta: &FileMeta) -> bool {
    if !program.triggers.is_empty() {
        return false;
    }
    let mut names: Vec<&str> = Vec::new();
    for leaf in disjuncts(term) {
        let Expr::Branch(name) = leaf else { return false };
        let Some(b) = meta.branch(name) else { return false };
        if b.desc.kind != BranchKind::Scalar {
            return false;
        }
        names.push(name);
    }
    let cols: Vec<usize> = names.iter().map(|n| program.scalar_col(n)).collect();
    program.triggers = cols;
    true
}

/// Resolve branch references to column indices, producing the
/// interpreter-ready [`CExpr`]. Assumes `shape_of` validated the
/// expression (branches exist, aggregations are object-shaped).
fn compile_expr(program: &mut CutProgram, e: &Expr, meta: &FileMeta) -> Result<CExpr> {
    Ok(match e {
        Expr::Num(v) => CExpr::Num(*v as f32),
        Expr::Branch(name) => {
            let b = meta
                .branch(name)
                .ok_or_else(|| Error::query(format!("cut references unknown branch '{name}'")))?;
            match b.desc.kind {
                BranchKind::Scalar => CExpr::Scalar(program.scalar_col(name)),
                BranchKind::Jagged => {
                    if b.desc.dtype != DType::F32 {
                        return Err(Error::query(format!(
                            "cut variable '{name}' must be f32 (got {})",
                            b.desc.dtype.name()
                        )));
                    }
                    CExpr::Jagged(program.obj_col(name))
                }
            }
        }
        Expr::Unary(op, x) => CExpr::Unary(*op, Box::new(compile_expr(program, x, meta)?)),
        Expr::Binary(op, a, b) => CExpr::Binary(
            *op,
            Box::new(compile_expr(program, a, meta)?),
            Box::new(compile_expr(program, b, meta)?),
        ),
        Expr::Agg { op, arg, pred } => {
            let carg = compile_expr(program, arg, meta)?;
            let cpred = match pred {
                Some(p) => Some(Box::new(compile_expr(program, p, meta)?)),
                None => None,
            };
            let nobj = first_jagged(&carg)
                .or_else(|| cpred.as_deref().and_then(first_jagged))
                .ok_or_else(|| {
                    Error::query(format!(
                        "aggregation '{}' does not reference a jagged branch",
                        op.name()
                    ))
                })?;
            CExpr::Agg { op: *op, nobj, arg: Box::new(carg), pred: cpred }
        }
    })
}

/// First jagged column referenced at object shape (nested aggregations
/// are event-shaped and do not count).
fn first_jagged(e: &CExpr) -> Option<usize> {
    match e {
        CExpr::Jagged(c) => Some(*c),
        CExpr::Num(_) | CExpr::Scalar(_) | CExpr::Agg { .. } => None,
        CExpr::Unary(_, x) => first_jagged(x),
        CExpr::Binary(_, a, b) => first_jagged(a).or_else(|| first_jagged(b)),
        CExpr::Shared(x) => first_jagged(x),
    }
}

// ---- common-subexpression elimination --------------------------------

/// Is `e` a leaf (literal or bare column read)? Leaves are never worth
/// sharing — the "scratch column" would just copy the input column.
fn cse_leaf(e: &CExpr) -> bool {
    matches!(e, CExpr::Num(_) | CExpr::Scalar(_) | CExpr::Jagged(_))
}

fn cse_count(e: &CExpr, counts: &mut std::collections::BTreeMap<String, u32>) {
    if !cse_leaf(e) {
        *counts.entry(format!("{e:?}")).or_insert(0) += 1;
    }
    match e {
        CExpr::Num(_) | CExpr::Scalar(_) | CExpr::Jagged(_) => {}
        CExpr::Unary(_, x) => cse_count(x, counts),
        CExpr::Binary(_, a, b) => {
            cse_count(a, counts);
            cse_count(b, counts);
        }
        CExpr::Agg { arg, pred, .. } => {
            cse_count(arg, counts);
            if let Some(p) = pred {
                cse_count(p, counts);
            }
        }
        CExpr::Shared(x) => cse_count(x, counts),
    }
}

fn cse_rewrite_children(
    e: CExpr,
    counts: &std::collections::BTreeMap<String, u32>,
    cache: &mut std::collections::BTreeMap<String, Arc<CExpr>>,
) -> CExpr {
    match e {
        CExpr::Unary(op, x) => CExpr::Unary(op, Box::new(cse_rewrite(*x, counts, cache))),
        CExpr::Binary(op, a, b) => CExpr::Binary(
            op,
            Box::new(cse_rewrite(*a, counts, cache)),
            Box::new(cse_rewrite(*b, counts, cache)),
        ),
        CExpr::Agg { op, nobj, arg, pred } => CExpr::Agg {
            op,
            nobj,
            arg: Box::new(cse_rewrite(*arg, counts, cache)),
            pred: pred.map(|p| Box::new(cse_rewrite(*p, counts, cache))),
        },
        other => other,
    }
}

/// Top-down rewrite: the first occurrence of a repeated subtree
/// becomes the canonical shared node (with its own children
/// recursively rewritten, so nested repeats share too); every later
/// structurally-equal occurrence points at the same [`Arc`].
fn cse_rewrite(
    e: CExpr,
    counts: &std::collections::BTreeMap<String, u32>,
    cache: &mut std::collections::BTreeMap<String, Arc<CExpr>>,
) -> CExpr {
    if !cse_leaf(&e) {
        let key = format!("{e:?}");
        if counts.get(&key).copied().unwrap_or(0) >= 2 {
            if let Some(arc) = cache.get(&key) {
                return CExpr::Shared(arc.clone());
            }
            let arc = Arc::new(cse_rewrite_children(e, counts, cache));
            cache.insert(key, arc.clone());
            return CExpr::Shared(arc);
        }
    }
    cse_rewrite_children(e, counts, cache)
}

/// The CSE pass over a program's residual expressions. Keys are the
/// (deterministic) `Debug` rendering of a subtree, so "common" means
/// structurally equal over resolved column indices. Conjunct count and
/// order are preserved — only the interior wiring changes.
fn cse_exprs(program: &mut CutProgram) {
    if program.exprs.is_empty() {
        return;
    }
    let mut counts = std::collections::BTreeMap::new();
    for e in &program.exprs {
        cse_count(e, &mut counts);
    }
    if !counts.values().any(|&c| c >= 2) {
        return;
    }
    let mut cache = std::collections::BTreeMap::new();
    let exprs = std::mem::take(&mut program.exprs);
    program.exprs =
        exprs.into_iter().map(|e| cse_rewrite(e, &counts, &mut cache)).collect();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::troot::{BranchDesc, BranchMeta, FileMeta};

    fn meta() -> FileMeta {
        let mk_scalar = |n: &str, d| BranchMeta {
            desc: BranchDesc::scalar(n, d),
            baskets: vec![],
        };
        let mk_jagged = |n: &str, g: &str| BranchMeta {
            desc: BranchDesc::jagged(n, DType::F32, g),
            baskets: vec![],
        };
        FileMeta {
            n_events: 0,
            codec: crate::compress::Codec::Lz4,
            basket_events: 1000,
            branches: vec![
                mk_scalar("nElectron", DType::I32),
                mk_jagged("Electron_pt", "Electron"),
                mk_jagged("Electron_eta", "Electron"),
                mk_jagged("Muon_pt", "Muon"),
                mk_jagged("Jet_pt", "Jet"),
                mk_scalar("MET_pt", DType::F32),
                mk_scalar("HLT_IsoMu24", DType::U8),
                mk_scalar("HLT_Ele32_WPTight", DType::U8),
                mk_scalar("HLT_Rare_v1", DType::U8),
                mk_scalar("run", DType::I64),
            ],
        }
    }

    fn query(text: &str) -> SkimQuery {
        SkimQuery::from_json_text(text).unwrap()
    }

    const Q: &str = r#"{
        "input": "f.troot", "output": "o.troot",
        "branches": ["Electron_*", "Jet_pt", "MET_pt", "HLT_*", "run"],
        "selection": {
            "preselection": [ {"branch": "nElectron", "op": ">=", "value": 1} ],
            "objects": [
                { "collection": "Electron", "min_count": 1, "cuts": [
                    {"var": "Electron_pt",  "op": ">",   "value": 25.0},
                    {"var": "Electron_eta", "op": "|<|", "value": 2.4} ] }
            ],
            "event": {
                "ht": {"jet_pt": "Jet_pt", "object_pt_min": 30.0, "min": 200.0},
                "triggers_any": ["HLT_IsoMu24"]
            }
        }
    }"#;

    #[test]
    fn two_phase_split() {
        let plan = SkimPlan::build(&query(Q), &meta()).unwrap();
        // Criteria = what the selection reads.
        assert_eq!(
            plan.criteria_branches,
            vec!["nElectron", "Electron_pt", "Electron_eta", "Jet_pt", "HLT_IsoMu24"]
        );
        // Output-only = selected minus criteria.
        for b in ["MET_pt", "HLT_Ele32_WPTight", "run"] {
            assert!(plan.output_only_branches.iter().any(|x| x == b), "missing {b}");
        }
        assert!(!plan.output_only_branches.iter().any(|x| x == "Electron_pt"));
        // Curated mapping dropped HLT_Rare_v1.
        assert!(!plan.output_branches.iter().any(|x| x == "HLT_Rare_v1"));
        assert!(plan.warnings.iter().any(|w| w.contains("curated")));
    }

    #[test]
    fn program_compilation() {
        let plan = SkimPlan::build(&query(Q), &meta()).unwrap();
        let p = &plan.program;
        assert_eq!(p.obj_columns, vec!["Electron_pt", "Electron_eta", "Jet_pt"]);
        assert_eq!(p.scalar_columns, vec!["nElectron", "HLT_IsoMu24"]);
        assert_eq!(p.obj_cuts.len(), 2);
        assert_eq!(p.obj_cuts[0], ObjCutParam { col: 0, op: 0, abs: false, value: 25.0 });
        assert_eq!(p.obj_cuts[1], ObjCutParam { col: 1, op: 2, abs: true, value: 2.4 });
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.groups[0].cut_range, 0..2);
        let ht = p.ht.as_ref().unwrap();
        assert_eq!(ht.col, 2);
        assert_eq!(ht.min_ht, 200.0);
        assert_eq!(p.triggers, vec![1]);
        assert!(p.exprs.is_empty());
        assert!(p.fits_kernel());
    }

    #[test]
    fn zone_predicates_cover_every_prunable_conjunct() {
        let plan = SkimPlan::build(&query(Q), &meta()).unwrap();
        // 1 scalar cut + 2 object cuts (min_count 1) + HT + trigger OR.
        assert_eq!(plan.zone_predicates.len(), 5);
        assert_eq!(
            plan.zone_predicates[0],
            ZonePredicate::Cmp(ZoneCmp {
                branch: "nElectron".into(),
                op: 1,
                abs: false,
                value: 1.0
            })
        );
        assert_eq!(
            plan.zone_predicates[2],
            ZonePredicate::Cmp(ZoneCmp {
                branch: "Electron_eta".into(),
                op: 2,
                abs: true,
                value: 2.4
            })
        );
        // HT compiles to "some jet above object_pt_min".
        assert_eq!(
            plan.zone_predicates[3],
            ZonePredicate::Cmp(ZoneCmp {
                branch: "Jet_pt".into(),
                op: 0,
                abs: false,
                value: 30.0
            })
        );
        // Triggers compile to an OR over flags > 0.5.
        assert_eq!(
            plan.zone_predicates[4],
            ZonePredicate::Or(vec![ZoneCmp {
                branch: "HLT_IsoMu24".into(),
                op: 0,
                abs: false,
                value: 0.5
            }])
        );
    }

    #[test]
    fn zone_predicates_skip_unprunable_conjuncts() {
        // A copy-all query prunes nothing.
        let q = query(r#"{"input": "f", "output": "o", "branches": ["MET_pt"]}"#);
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        assert!(plan.zone_predicates.is_empty());
        // min_count = 0 groups hold vacuously — no predicate.
        let q = query(
            r#"{"input": "f", "output": "o", "branches": ["MET_pt"],
                "selection": {"objects": [
                    {"collection": "Electron", "min_count": 0, "cuts": [
                        {"var": "Electron_pt", "op": ">", "value": 25.0}]}]}}"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        assert!(plan.zone_predicates.is_empty());
        // Residual IR expressions never produce predicates.
        let q = query(
            r#"{"input": "f", "output": "o", "branches": ["MET_pt"],
                "cut": "MET_pt + nElectron > 3"}"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        assert!(!plan.program.exprs.is_empty());
        assert!(plan.zone_predicates.is_empty());
    }

    #[test]
    fn zone_predicate_death_against_an_index() {
        use crate::index::{BasketSummary, BranchZones, FileIndex};
        let idx = FileIndex {
            digest: 0,
            n_events: 4,
            basket_events: 2,
            branches: vec![
                BranchZones {
                    name: "MET_pt".into(),
                    baskets: vec![
                        BasketSummary { min: 10.0, max: 40.0, n_values: 2, n_nan: 0 },
                        BasketSummary { min: 90.0, max: 120.0, n_values: 2, n_nan: 0 },
                    ],
                },
                BranchZones {
                    name: "HLT_IsoMu24".into(),
                    baskets: vec![
                        BasketSummary { min: 0.0, max: 0.0, n_values: 2, n_nan: 0 },
                        BasketSummary { min: 0.0, max: 1.0, n_values: 2, n_nan: 0 },
                    ],
                },
            ],
        };
        let cut = |value: f32| {
            ZonePredicate::Cmp(ZoneCmp { branch: "MET_pt".into(), op: 0, abs: false, value })
        };
        assert!(cut(50.0).dead(&idx, 0));
        assert!(!cut(50.0).dead(&idx, 1));
        assert!(!cut(5.0).dead(&idx, 0));
        // Unknown branch / out-of-range basket: never dead.
        let unknown = ZonePredicate::Cmp(ZoneCmp {
            branch: "nope".into(),
            op: 0,
            abs: false,
            value: 1e9,
        });
        assert!(!unknown.dead(&idx, 0));
        assert!(!cut(50.0).dead(&idx, 7));
        // Trigger OR: dead only when every flag is all-zero.
        let or = ZonePredicate::Or(vec![ZoneCmp {
            branch: "HLT_IsoMu24".into(),
            op: 0,
            abs: false,
            value: 0.5,
        }]);
        assert!(or.dead(&idx, 0));
        assert!(!or.dead(&idx, 1));
        assert!(!ZonePredicate::Or(Vec::new()).dead(&idx, 0));
    }

    #[test]
    fn column_sources_intern_to_criteria_ids() {
        // Every program column maps to the dense id of its criteria
        // branch — the engine indexes per-cluster basket Vecs with
        // these, so the mapping must be exact and total.
        let plan = SkimPlan::build(&query(Q), &meta()).unwrap();
        let p = &plan.program;
        assert_eq!(plan.obj_col_branch.len(), p.obj_columns.len());
        assert_eq!(plan.scalar_col_branch.len(), p.scalar_columns.len());
        for (c, name) in p.obj_columns.iter().enumerate() {
            let id = plan.obj_col_branch[c];
            assert_eq!(&plan.criteria_branches[id.idx()], name);
        }
        for (s, name) in p.scalar_columns.iter().enumerate() {
            let id = plan.scalar_col_branch[s];
            assert_eq!(&plan.criteria_branches[id.idx()], name);
        }
    }

    #[test]
    fn lowered_ir_compiles_to_identical_program() {
        // The acceptance invariant: a legacy structured query and the
        // same query expressed purely as its lowered IR cut compile to
        // the *identical* CutProgram (stage classification reverses
        // the lowering), so masks and the kernel-fit decision match.
        let q_legacy = query(Q);
        let mut q_ir = q_legacy.clone();
        q_ir.cut = q_legacy.selection.to_expr();
        q_ir.selection = Default::default();
        let plan_legacy = SkimPlan::build(&q_legacy, &meta()).unwrap();
        let plan_ir = SkimPlan::build(&q_ir, &meta()).unwrap();
        assert_eq!(plan_legacy.program, plan_ir.program);
        assert_eq!(plan_legacy.criteria_branches, plan_ir.criteria_branches);
        assert_eq!(plan_legacy.output_only_branches, plan_ir.output_only_branches);
        assert!(plan_ir.program.fits_kernel());

        // Non-u8 trigger branches classify identically too (the
        // legacy bank accepts any scalar dtype; so must the IR path).
        let q_odd = query(
            r#"{"input": "f", "output": "o", "branches": ["MET_pt"],
                "selection": {"event": {"triggers_any": ["MET_pt", "run"]}}}"#,
        );
        let mut q_odd_ir = q_odd.clone();
        q_odd_ir.cut = q_odd.selection.to_expr();
        q_odd_ir.selection = Default::default();
        let p_odd = SkimPlan::build(&q_odd, &meta()).unwrap();
        let p_odd_ir = SkimPlan::build(&q_odd_ir, &meta()).unwrap();
        assert_eq!(p_odd.program, p_odd_ir.program);
        assert_eq!(p_odd.program.triggers.len(), 2);
        assert!(p_odd_ir.program.exprs.is_empty());
    }

    #[test]
    fn cut_string_classifies_into_kernel_stages() {
        let q = query(
            r#"{"input": "f", "output": "o", "branches": ["MET_pt"],
                "cut": "nElectron >= 1 && count(Electron_pt > 25 && abs(Electron_eta) < 2.4) >= 1 && sum(Jet_pt[Jet_pt > 30]) >= 200 && HLT_IsoMu24"}"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        let p = &plan.program;
        assert_eq!(p.scalar_cuts.len(), 1);
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.obj_cuts.len(), 2);
        assert_eq!(p.groups[0].collection, "Electron");
        assert!(p.ht.is_some());
        assert_eq!(p.triggers.len(), 1);
        assert!(p.exprs.is_empty());
        assert!(p.fits_kernel(), "kernel-expressible cut string must fit");
    }

    #[test]
    fn residual_expressions_disable_kernel() {
        let q = query(
            r#"{"input": "f", "output": "o", "branches": ["MET_pt"],
                "cut": "MET_pt > 100 || sum(Jet_pt[Jet_pt > 30]) > 250"}"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        let p = &plan.program;
        assert_eq!(p.exprs.len(), 1);
        assert!(!p.fits_kernel());
        let reasons = p.kernel_unfit_reasons();
        assert!(reasons.iter().any(|r| r.contains("residual")), "{reasons:?}");
        assert!(plan.warnings.iter().any(|w| w.contains("interpreter")));
        // The jagged column is still a phase-1 criteria branch.
        assert!(plan.criteria_branches.iter().any(|b| b == "Jet_pt"));
    }

    #[test]
    fn object_shaped_cut_gets_implicit_any() {
        // A bare per-object cut is implicitly `any(..)`, which
        // classifies as `count(..) >= 1` — it stays kernel-eligible.
        let q = query(
            r#"{"input": "f", "output": "o", "branches": ["MET_pt"],
                "cut": "Muon_pt > 30"}"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        let p = &plan.program;
        assert!(p.exprs.is_empty());
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.groups[0].collection, "Muon");
        assert_eq!(p.groups[0].min_count, 1);
        assert!(p.fits_kernel());

        // A non-simple object predicate still lands in the residual IR.
        let q = query(
            r#"{"input": "f", "output": "o", "branches": ["MET_pt"],
                "cut": "Muon_pt * 2 > 30"}"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        assert_eq!(plan.program.exprs.len(), 1);
        match &plan.program.exprs[0] {
            CExpr::Agg { op: AggOp::Any, .. } => {}
            other => panic!("expected implicit any(), got {other:?}"),
        }
    }

    #[test]
    fn implicit_any_is_per_conjunct() {
        // Event-level conjuncts keep their kernel classification even
        // when an object-shaped conjunct sits next to them
        // (`A && obj` ≡ `A && any(obj)`).
        let q = query(
            r#"{"input": "f", "output": "o", "branches": ["MET_pt"],
                "cut": "MET_pt > 100 && Muon_pt > 30"}"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        let p = &plan.program;
        assert_eq!(p.scalar_cuts.len(), 1);
        assert_eq!(p.groups.len(), 1);
        assert!(p.exprs.is_empty());
        assert!(p.fits_kernel());
    }

    #[test]
    fn mixed_collections_in_one_expression_rejected() {
        let q = query(
            r#"{"input": "f", "output": "o", "branches": ["MET_pt"],
                "cut": "any(Muon_pt > Electron_pt)"}"#,
        );
        let err = SkimPlan::build(&q, &meta()).unwrap_err();
        assert!(format!("{err}").contains("different collections"), "{err}");
    }

    #[test]
    fn cut_unknown_branch_and_bad_aggregation_rejected() {
        for (cut, needle) in [
            ("nTau >= 1", "unknown branch 'nTau'"),
            ("count(MET_pt > 30) >= 1", "requires a per-object"),
        ] {
            let text = format!(
                r#"{{"input": "f", "output": "o", "branches": ["MET_pt"], "cut": "{cut}"}}"#
            );
            let err = SkimPlan::build(&query(&text), &meta()).unwrap_err();
            assert!(format!("{err}").contains(needle), "cut '{cut}': {err}");
        }
    }

    #[test]
    fn unknown_branch_rejected() {
        let bad = Q.replace("nElectron", "nTau");
        assert!(SkimPlan::build(&query(&bad), &meta()).is_err());
    }

    #[test]
    fn kind_mismatch_rejected() {
        // MET_pt is scalar; using it as an object cut must fail.
        let bad = r#"{
            "input": "f", "output": "o", "branches": ["*"],
            "selection": {"objects": [{"collection": "MET", "cuts": [
                {"var": "MET_pt", "op": ">", "value": 1}]}]}
        }"#;
        assert!(SkimPlan::build(&query(bad), &meta()).is_err());
    }

    #[test]
    fn empty_selection_is_copy_all() {
        let q = query(r#"{"input": "f", "output": "o", "branches": ["Electron_*"]}"#);
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        assert!(plan.criteria_branches.is_empty());
        assert_eq!(plan.output_only_branches, plan.output_branches);
        assert!(plan.program.fits_kernel());
        assert!(plan.program.is_trivial());
    }

    #[test]
    fn no_matching_branches_is_error() {
        let q = query(r#"{"input": "f", "output": "o", "branches": ["Tau_*"]}"#);
        assert!(SkimPlan::build(&q, &meta()).is_err());
    }

    #[test]
    fn criteria_branch_outside_output_is_not_written() {
        // The selection reads nElectron/HLT_IsoMu24, but the output
        // keeps only MET_pt: criteria stay criteria (phase 1) without
        // leaking into the output schema, and the only selected branch
        // is output-only (phase 2).
        let q = query(
            r#"{
                "input": "f", "output": "o", "branches": ["MET_pt"],
                "selection": {
                    "preselection": [ {"branch": "nElectron", "op": ">=", "value": 1} ],
                    "event": {"triggers_any": ["HLT_IsoMu24"]}
                }
            }"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        assert_eq!(plan.output_branches, vec!["MET_pt"]);
        assert_eq!(plan.criteria_branches, vec!["nElectron", "HLT_IsoMu24"]);
        assert_eq!(plan.output_only_branches, vec!["MET_pt"]);
        assert!(!plan.output_branches.contains(&"nElectron".to_string()));
    }

    #[test]
    fn criteria_in_output_are_not_output_only() {
        // Branches both selected and read by the selection are phase-1
        // gathers, never phase-2 fetches.
        let q = query(
            r#"{
                "input": "f", "output": "o",
                "branches": ["MET_pt", "Jet_pt"],
                "selection": {
                    "event": {"ht": {"jet_pt": "Jet_pt", "object_pt_min": 30.0, "min": 100.0}}
                }
            }"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        assert_eq!(plan.criteria_branches, vec!["Jet_pt"]);
        assert_eq!(plan.output_only_branches, vec!["MET_pt"]);
        // Output keeps schema order regardless of criteria membership.
        assert_eq!(plan.output_branches, vec!["Jet_pt", "MET_pt"]);
    }

    #[test]
    fn curated_mapping_respects_force_all_in_plan() {
        let forced = Q.replace(r#""branches":"#, r#""force_all": true, "branches":"#);
        let plan = SkimPlan::build(&query(&forced), &meta()).unwrap();
        // With force_all, the rare trigger survives into the output.
        assert!(plan.output_branches.iter().any(|x| x == "HLT_Rare_v1"));
        assert!(!plan.warnings.iter().any(|w| w.contains("curated")));
        // And it lands in phase 2 (output-only), not in the criteria.
        assert!(plan.output_only_branches.iter().any(|x| x == "HLT_Rare_v1"));
        assert!(!plan.criteria_branches.iter().any(|x| x == "HLT_Rare_v1"));
    }

    #[test]
    fn oversized_program_warns_not_fails() {
        // 13 distinct object columns > KERNEL_MAX_OBJ_COLS.
        let mut branches = String::new();
        let mut cuts = String::new();
        for i in 0..13 {
            if i > 0 {
                cuts.push(',');
            }
            cuts.push_str(&format!(
                r#"{{"var": "Jet_v{i}", "op": ">", "value": 1}}"#
            ));
            branches.push_str(&format!(r#","Jet_v{i}""#));
        }
        let text = format!(
            r#"{{"input": "f", "output": "o", "branches": ["Jet_pt"{branches}],
                "selection": {{"objects": [{{"collection": "Jet", "cuts": [{cuts}]}}]}}}}"#
        );
        let mut m = meta();
        for i in 0..13 {
            m.branches.push(BranchMeta {
                desc: BranchDesc::jagged(format!("Jet_v{i}"), DType::F32, "Jet"),
                baskets: vec![],
            });
        }
        let plan = SkimPlan::build(&query(&text), &m).unwrap();
        assert!(!plan.program.fits_kernel());
        assert!(plan.warnings.iter().any(|w| w.contains("interpreter")));
    }

    #[test]
    fn explain_renders_plan_and_fallback_reason() {
        let q = query(
            r#"{"input": "f.troot", "output": "o.troot", "branches": ["MET_pt"],
                "cut": "MET_pt > 100 || sum(Jet_pt[Jet_pt > 30]) > 250"}"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        let text = plan.explain(&q);
        assert!(text.contains("selection expression:"));
        assert!(text.contains("||"));
        assert!(text.contains("phase 1 (criteria)"));
        assert!(text.contains("MET_pt"));
        assert!(text.contains("scalar interpreter — kernel fallback because:"));
        assert!(text.contains("residual IR expression"));

        let fit = SkimPlan::build(&query(Q), &meta()).unwrap();
        let text = fit.explain(&query(Q));
        assert!(text.contains("vectorized AOT kernel"));
    }

    /// Collect the addresses of every [`CExpr::Shared`] node in `e`.
    fn shared_ptrs(e: &CExpr, out: &mut Vec<usize>) {
        match e {
            CExpr::Num(_) | CExpr::Scalar(_) | CExpr::Jagged(_) => {}
            CExpr::Unary(_, x) => shared_ptrs(x, out),
            CExpr::Binary(_, a, b) => {
                shared_ptrs(a, out);
                shared_ptrs(b, out);
            }
            CExpr::Agg { arg, pred, .. } => {
                shared_ptrs(arg, out);
                if let Some(p) = pred {
                    shared_ptrs(p, out);
                }
            }
            CExpr::Shared(x) => {
                out.push(std::sync::Arc::as_ptr(x) as usize);
                shared_ptrs(x, out);
            }
        }
    }

    #[test]
    fn cse_hoists_repeats_within_one_conjunct() {
        let q = query(
            r#"{"input": "f", "output": "o", "branches": ["MET_pt"],
                "cut": "MET_pt + nElectron > 3 || MET_pt + nElectron < 1"}"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        assert_eq!(plan.program.exprs.len(), 1);
        let mut ptrs = Vec::new();
        shared_ptrs(&plan.program.exprs[0], &mut ptrs);
        // The repeated `MET_pt + nElectron` is one shared node with two
        // occurrences.
        assert_eq!(ptrs.len(), 2, "{:?}", plan.program.exprs[0]);
        assert_eq!(ptrs[0], ptrs[1]);
    }

    #[test]
    fn cse_shares_across_conjuncts_and_skips_unique_trees() {
        // `max(Jet_pt)` appears in both residual conjuncts: one Arc,
        // two occurrences, conjunct count unchanged.
        let q = query(
            r#"{"input": "f", "output": "o", "branches": ["MET_pt"],
                "cut": "max(Jet_pt) > 60 && max(Jet_pt) + MET_pt > 100"}"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        assert_eq!(plan.program.exprs.len(), 2);
        let mut ptrs = Vec::new();
        for e in &plan.program.exprs {
            shared_ptrs(e, &mut ptrs);
        }
        assert_eq!(ptrs.len(), 2, "{:?}", plan.program.exprs);
        assert_eq!(ptrs[0], ptrs[1]);

        // No repeats → the pass is a no-op (no Shared nodes at all).
        let q = query(
            r#"{"input": "f", "output": "o", "branches": ["MET_pt"],
                "cut": "MET_pt + nElectron > 3"}"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        let mut ptrs = Vec::new();
        for e in &plan.program.exprs {
            shared_ptrs(e, &mut ptrs);
        }
        assert!(ptrs.is_empty(), "{:?}", plan.program.exprs);
    }

    #[test]
    fn cse_preserves_structural_program_equality() {
        // Two builds of the same query produce equal programs (derived
        // PartialEq compares Shared pointees structurally).
        let text = r#"{"input": "f", "output": "o", "branches": ["MET_pt"],
            "cut": "MET_pt + nElectron > 3 || MET_pt + nElectron < 1"}"#;
        let a = SkimPlan::build(&query(text), &meta()).unwrap();
        let b = SkimPlan::build(&query(text), &meta()).unwrap();
        assert_eq!(a.program, b.program);
    }
}
