//! Query planning: branch categorization + cut-program compilation
//! (§3.1–3.2).
//!
//! Given a parsed [`SkimQuery`] and the file schema, the planner:
//!
//! 1. expands the output branch patterns (curated `HLT_*` mapping
//!    included) → the branches written to the filtered file;
//! 2. splits branches into **filtering criteria** (read in phase 1,
//!    O(10) in NanoAOD practice) and **output-only** (read in phase 2,
//!    only for passing events, O(100)) — the two-phase split that
//!    removes most data movement;
//! 3. compiles the selection into a numeric [`CutProgram`]: flat column
//!    lists + opcode/threshold banks consumed identically by the Rust
//!    scalar interpreter and the AOT Pallas kernel (which has fixed
//!    capacity; programs exceeding it fall back to the interpreter).

use super::ast::SkimQuery;
use super::wildcard;
use crate::troot::{BranchKind, DType, FileMeta};
use crate::{Error, Result};

/// Kernel capacity (must match `python/compile/kernels/skim.py`).
pub const KERNEL_MAX_OBJ_COLS: usize = 12;
pub const KERNEL_MAX_SCALAR_COLS: usize = 16;
pub const KERNEL_MAX_OBJ_CUTS: usize = 12;
pub const KERNEL_MAX_SCALAR_CUTS: usize = 6;
pub const KERNEL_MAX_GROUPS: usize = 4;

/// One compiled per-object cut: `col` indexes [`CutProgram::obj_columns`].
#[derive(Debug, Clone, PartialEq)]
pub struct ObjCutParam {
    pub col: usize,
    /// 0 `>` · 1 `>=` · 2 `<` · 3 `<=` · 4 `==` · 5 `!=`
    pub op: u8,
    pub abs: bool,
    pub value: f32,
}

/// One compiled scalar cut: `col` indexes [`CutProgram::scalar_columns`].
#[derive(Debug, Clone, PartialEq)]
pub struct ScalarCutParam {
    pub col: usize,
    pub op: u8,
    pub abs: bool,
    pub value: f32,
}

/// A collection's object-level requirement: at least `min_count`
/// objects passing all cuts in `cut_range` (indices into
/// [`CutProgram::obj_cuts`]). All of a group's cut columns share the
/// same collection, hence the same multiplicity.
#[derive(Debug, Clone, PartialEq)]
pub struct ObjGroup {
    pub collection: String,
    pub cut_range: std::ops::Range<usize>,
    pub min_count: u32,
}

/// Compiled HT requirement.
#[derive(Debug, Clone, PartialEq)]
pub struct HtParam {
    /// Index into `obj_columns` of the jet-pT column.
    pub col: usize,
    pub object_pt_min: f32,
    pub min_ht: f32,
}

/// The numeric, engine-agnostic form of a selection.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CutProgram {
    /// Jagged f32 columns the program reads (order = kernel column ids).
    pub obj_columns: Vec<String>,
    /// Scalar columns (f32-convertible) the program reads.
    pub scalar_columns: Vec<String>,
    pub obj_cuts: Vec<ObjCutParam>,
    pub groups: Vec<ObjGroup>,
    /// Preselection scalar cuts (ANDed).
    pub scalar_cuts: Vec<ScalarCutParam>,
    pub ht: Option<HtParam>,
    /// Indices into `scalar_columns` of trigger flags (ORed; empty =
    /// no trigger requirement).
    pub triggers: Vec<usize>,
}

impl CutProgram {
    /// Does this program fit the AOT kernel's fixed capacity?
    pub fn fits_kernel(&self) -> bool {
        self.obj_columns.len() <= KERNEL_MAX_OBJ_COLS
            && self.scalar_columns.len() <= KERNEL_MAX_SCALAR_COLS
            && self.obj_cuts.len() <= KERNEL_MAX_OBJ_CUTS
            && self.scalar_cuts.len() + self.triggers.len() <= KERNEL_MAX_SCALAR_CUTS + KERNEL_MAX_SCALAR_COLS
            && self.groups.len() + self.ht.is_some() as usize <= KERNEL_MAX_GROUPS + 1
    }

    fn obj_col(&mut self, name: &str) -> usize {
        match self.obj_columns.iter().position(|c| c == name) {
            Some(i) => i,
            None => {
                self.obj_columns.push(name.to_string());
                self.obj_columns.len() - 1
            }
        }
    }

    fn scalar_col(&mut self, name: &str) -> usize {
        match self.scalar_columns.iter().position(|c| c == name) {
            Some(i) => i,
            None => {
                self.scalar_columns.push(name.to_string());
                self.scalar_columns.len() - 1
            }
        }
    }
}

/// The full execution plan for one skim job.
#[derive(Debug, Clone)]
pub struct SkimPlan {
    /// Branches written to the output file (schema order).
    pub output_branches: Vec<String>,
    /// Branches read in phase 1 to evaluate the selection.
    pub criteria_branches: Vec<String>,
    /// Output branches *not* needed for filtering — fetched in phase 2,
    /// only for events that passed.
    pub output_only_branches: Vec<String>,
    pub program: CutProgram,
    pub warnings: Vec<String>,
}

impl SkimPlan {
    /// Build a plan: expand patterns, validate branches against the
    /// schema, compile the cut program.
    pub fn build(query: &SkimQuery, meta: &FileMeta) -> Result<SkimPlan> {
        let schema: Vec<&str> = meta.branch_names().collect();
        let expansion = wildcard::expand(&query.branches, &schema, query.force_all);
        let mut warnings = expansion.warnings;
        if expansion.selected.is_empty() {
            return Err(Error::query("no output branches selected"));
        }

        // --- validate + compile the selection --------------------------
        let mut program = CutProgram::default();

        let require = |name: &str, kind: BranchKind| -> Result<DType> {
            let b = meta
                .branch(name)
                .ok_or_else(|| Error::query(format!("selection references unknown branch '{name}'")))?;
            if b.desc.kind != kind {
                return Err(Error::query(format!(
                    "branch '{name}' is {:?}, expected {:?}",
                    b.desc.kind, kind
                )));
            }
            Ok(b.desc.dtype)
        };

        for cut in &query.selection.preselection {
            require(&cut.branch, BranchKind::Scalar)?;
            let col = program.scalar_col(&cut.branch);
            let (op, abs) = cut.op.code();
            program.scalar_cuts.push(ScalarCutParam { col, op, abs, value: cut.value as f32 });
        }

        for sel in &query.selection.objects {
            let start = program.obj_cuts.len();
            for cut in &sel.cuts {
                let dtype = require(&cut.var, BranchKind::Jagged)?;
                if dtype != DType::F32 {
                    return Err(Error::query(format!(
                        "object cut variable '{}' must be f32 (got {})",
                        cut.var,
                        dtype.name()
                    )));
                }
                let col = program.obj_col(&cut.var);
                let (op, abs) = cut.op.code();
                program.obj_cuts.push(ObjCutParam { col, op, abs, value: cut.value as f32 });
            }
            program.groups.push(ObjGroup {
                collection: sel.collection.clone(),
                cut_range: start..program.obj_cuts.len(),
                min_count: sel.min_count,
            });
        }

        if let Some(ht) = &query.selection.event.ht {
            let dtype = require(&ht.jet_pt, BranchKind::Jagged)?;
            if dtype != DType::F32 {
                return Err(Error::query("HT jet_pt branch must be f32"));
            }
            let col = program.obj_col(&ht.jet_pt);
            program.ht = Some(HtParam {
                col,
                object_pt_min: ht.object_pt_min as f32,
                min_ht: ht.min as f32,
            });
        }

        for trig in &query.selection.event.triggers_any {
            require(trig, BranchKind::Scalar)?;
            let col = program.scalar_col(trig);
            program.triggers.push(col);
        }

        // --- two-phase branch split ------------------------------------
        let criteria = query.selection.referenced_branches();
        for c in &criteria {
            // Criteria branches must exist even if not in the output.
            if meta.branch(c).is_none() {
                return Err(Error::query(format!("criteria branch '{c}' not in file")));
            }
        }
        let output_only: Vec<String> = expansion
            .selected
            .iter()
            .filter(|b| !criteria.contains(b))
            .cloned()
            .collect();

        if !program.fits_kernel() {
            warnings.push(format!(
                "cut program exceeds AOT kernel capacity ({} obj cols, {} obj cuts): \
                 vectorized path unavailable, scalar interpreter will be used",
                program.obj_columns.len(),
                program.obj_cuts.len()
            ));
        }

        Ok(SkimPlan {
            output_branches: expansion.selected,
            criteria_branches: criteria,
            output_only_branches: output_only,
            program,
            warnings,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::troot::{BranchDesc, BranchMeta, FileMeta};

    fn meta() -> FileMeta {
        let mk_scalar = |n: &str, d| BranchMeta {
            desc: BranchDesc::scalar(n, d),
            baskets: vec![],
        };
        let mk_jagged = |n: &str, g: &str| BranchMeta {
            desc: BranchDesc::jagged(n, DType::F32, g),
            baskets: vec![],
        };
        FileMeta {
            n_events: 0,
            codec: crate::compress::Codec::Lz4,
            basket_events: 1000,
            branches: vec![
                mk_scalar("nElectron", DType::I32),
                mk_jagged("Electron_pt", "Electron"),
                mk_jagged("Electron_eta", "Electron"),
                mk_jagged("Muon_pt", "Muon"),
                mk_jagged("Jet_pt", "Jet"),
                mk_scalar("MET_pt", DType::F32),
                mk_scalar("HLT_IsoMu24", DType::U8),
                mk_scalar("HLT_Ele32_WPTight", DType::U8),
                mk_scalar("HLT_Rare_v1", DType::U8),
                mk_scalar("run", DType::I64),
            ],
        }
    }

    fn query(text: &str) -> SkimQuery {
        SkimQuery::from_json_text(text).unwrap()
    }

    const Q: &str = r#"{
        "input": "f.troot", "output": "o.troot",
        "branches": ["Electron_*", "Jet_pt", "MET_pt", "HLT_*", "run"],
        "selection": {
            "preselection": [ {"branch": "nElectron", "op": ">=", "value": 1} ],
            "objects": [
                { "collection": "Electron", "min_count": 1, "cuts": [
                    {"var": "Electron_pt",  "op": ">",   "value": 25.0},
                    {"var": "Electron_eta", "op": "|<|", "value": 2.4} ] }
            ],
            "event": {
                "ht": {"jet_pt": "Jet_pt", "object_pt_min": 30.0, "min": 200.0},
                "triggers_any": ["HLT_IsoMu24"]
            }
        }
    }"#;

    #[test]
    fn two_phase_split() {
        let plan = SkimPlan::build(&query(Q), &meta()).unwrap();
        // Criteria = what the selection reads.
        assert_eq!(
            plan.criteria_branches,
            vec!["nElectron", "Electron_pt", "Electron_eta", "Jet_pt", "HLT_IsoMu24"]
        );
        // Output-only = selected minus criteria.
        for b in ["MET_pt", "HLT_Ele32_WPTight", "run"] {
            assert!(plan.output_only_branches.iter().any(|x| x == b), "missing {b}");
        }
        assert!(!plan.output_only_branches.iter().any(|x| x == "Electron_pt"));
        // Curated mapping dropped HLT_Rare_v1.
        assert!(!plan.output_branches.iter().any(|x| x == "HLT_Rare_v1"));
        assert!(plan.warnings.iter().any(|w| w.contains("curated")));
    }

    #[test]
    fn program_compilation() {
        let plan = SkimPlan::build(&query(Q), &meta()).unwrap();
        let p = &plan.program;
        assert_eq!(p.obj_columns, vec!["Electron_pt", "Electron_eta", "Jet_pt"]);
        assert_eq!(p.scalar_columns, vec!["nElectron", "HLT_IsoMu24"]);
        assert_eq!(p.obj_cuts.len(), 2);
        assert_eq!(p.obj_cuts[0], ObjCutParam { col: 0, op: 0, abs: false, value: 25.0 });
        assert_eq!(p.obj_cuts[1], ObjCutParam { col: 1, op: 2, abs: true, value: 2.4 });
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.groups[0].cut_range, 0..2);
        let ht = p.ht.as_ref().unwrap();
        assert_eq!(ht.col, 2);
        assert_eq!(ht.min_ht, 200.0);
        assert_eq!(p.triggers, vec![1]);
        assert!(p.fits_kernel());
    }

    #[test]
    fn unknown_branch_rejected() {
        let bad = Q.replace("nElectron", "nTau");
        assert!(SkimPlan::build(&query(&bad), &meta()).is_err());
    }

    #[test]
    fn kind_mismatch_rejected() {
        // MET_pt is scalar; using it as an object cut must fail.
        let bad = r#"{
            "input": "f", "output": "o", "branches": ["*"],
            "selection": {"objects": [{"collection": "MET", "cuts": [
                {"var": "MET_pt", "op": ">", "value": 1}]}]}
        }"#;
        assert!(SkimPlan::build(&query(bad), &meta()).is_err());
    }

    #[test]
    fn empty_selection_is_copy_all() {
        let q = query(r#"{"input": "f", "output": "o", "branches": ["Electron_*"]}"#);
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        assert!(plan.criteria_branches.is_empty());
        assert_eq!(plan.output_only_branches, plan.output_branches);
        assert!(plan.program.fits_kernel());
    }

    #[test]
    fn no_matching_branches_is_error() {
        let q = query(r#"{"input": "f", "output": "o", "branches": ["Tau_*"]}"#);
        assert!(SkimPlan::build(&q, &meta()).is_err());
    }

    #[test]
    fn criteria_branch_outside_output_is_not_written() {
        // The selection reads nElectron/HLT_IsoMu24, but the output
        // keeps only MET_pt: criteria stay criteria (phase 1) without
        // leaking into the output schema, and the only selected branch
        // is output-only (phase 2).
        let q = query(
            r#"{
                "input": "f", "output": "o", "branches": ["MET_pt"],
                "selection": {
                    "preselection": [ {"branch": "nElectron", "op": ">=", "value": 1} ],
                    "event": {"triggers_any": ["HLT_IsoMu24"]}
                }
            }"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        assert_eq!(plan.output_branches, vec!["MET_pt"]);
        assert_eq!(plan.criteria_branches, vec!["nElectron", "HLT_IsoMu24"]);
        assert_eq!(plan.output_only_branches, vec!["MET_pt"]);
        assert!(!plan.output_branches.contains(&"nElectron".to_string()));
    }

    #[test]
    fn criteria_in_output_are_not_output_only() {
        // Branches both selected and read by the selection are phase-1
        // gathers, never phase-2 fetches.
        let q = query(
            r#"{
                "input": "f", "output": "o",
                "branches": ["MET_pt", "Jet_pt"],
                "selection": {
                    "event": {"ht": {"jet_pt": "Jet_pt", "object_pt_min": 30.0, "min": 100.0}}
                }
            }"#,
        );
        let plan = SkimPlan::build(&q, &meta()).unwrap();
        assert_eq!(plan.criteria_branches, vec!["Jet_pt"]);
        assert_eq!(plan.output_only_branches, vec!["MET_pt"]);
        // Output keeps schema order regardless of criteria membership.
        assert_eq!(plan.output_branches, vec!["Jet_pt", "MET_pt"]);
    }

    #[test]
    fn curated_mapping_respects_force_all_in_plan() {
        let forced = Q.replace(r#""branches":"#, r#""force_all": true, "branches":"#);
        let plan = SkimPlan::build(&query(&forced), &meta()).unwrap();
        // With force_all, the rare trigger survives into the output.
        assert!(plan.output_branches.iter().any(|x| x == "HLT_Rare_v1"));
        assert!(!plan.warnings.iter().any(|w| w.contains("curated")));
        // And it lands in phase 2 (output-only), not in the criteria.
        assert!(plan.output_only_branches.iter().any(|x| x == "HLT_Rare_v1"));
        assert!(!plan.criteria_branches.iter().any(|x| x == "HLT_Rare_v1"));
    }

    #[test]
    fn oversized_program_warns_not_fails() {
        // 13 distinct object columns > KERNEL_MAX_OBJ_COLS.
        let mut branches = String::new();
        let mut cuts = String::new();
        for i in 0..13 {
            if i > 0 {
                cuts.push(',');
            }
            cuts.push_str(&format!(
                r#"{{"var": "Jet_v{i}", "op": ">", "value": 1}}"#
            ));
            branches.push_str(&format!(r#","Jet_v{i}""#));
        }
        let text = format!(
            r#"{{"input": "f", "output": "o", "branches": ["Jet_pt"{branches}],
                "selection": {{"objects": [{{"collection": "Jet", "cuts": [{cuts}]}}]}}}}"#
        );
        let mut m = meta();
        for i in 0..13 {
            m.branches.push(BranchMeta {
                desc: BranchDesc::jagged(format!("Jet_v{i}"), DType::F32, "Jet"),
                baskets: vec![],
            });
        }
        let plan = SkimPlan::build(&query(&text), &m).unwrap();
        assert!(!plan.program.fits_kernel());
        assert!(plan.warnings.iter().any(|w| w.contains("interpreter")));
    }
}
