//! Per-conjunct selectivity statistics — the measurement half of
//! selectivity-adaptive execution (ROADMAP item 3).
//!
//! The compiled [`CutProgram`] is a bag of ANDed **conjuncts** spread
//! over the kernel's fixed-function stages (scalar preselection cuts,
//! object groups, the HT unit, residual IR expressions, the trigger
//! OR). The fixed evaluators run them in stage order; the adaptive
//! evaluator ([`crate::engine::interp::eval_adaptive`]) runs them in
//! any order and records, per conjunct: events **visited** (alive when
//! the conjunct ran), events **passed**, and wall-clock **cost**.
//!
//! From those counts [`rank_order`] derives the classic
//! cost-over-kill-rate ordering: evaluate the conjunct with the
//! smallest `estimated_cost / (1 - pass_rate)` first, so cheap,
//! selective cuts kill events before expensive, permissive ones run.
//! The rank uses the *structural* cost estimate ([`Conjunct::cost`]),
//! not measured wall-clock, so the chosen order — and therefore every
//! funnel count — is a deterministic function of the data alone;
//! measured `cost_us` is carried for reporting only.
//!
//! Profiles are keyed by the conjunct's **canonical display string**
//! (stable across runs and processes), which lets a
//! [`SelectivityProfile`] ride the wire, persist next to a
//! materialized skim, and warm-start a repeat query.

use crate::query::expr::{AggOp, BinOp, UnaryOp};
use crate::query::plan::{CExpr, CutProgram};
use std::collections::BTreeMap;

/// Which compiled conjunct a [`Conjunct`] refers to (indices into the
/// owning [`CutProgram`]'s banks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConjunctKind {
    /// `scalar_cuts[i]` — one preselection comparison.
    Scalar(usize),
    /// `groups[i]` — one object-group requirement.
    Group(usize),
    /// The HT unit.
    Ht,
    /// `exprs[i]` — one residual IR expression.
    Residual(usize),
    /// The trigger OR bank (one conjunct for the whole bank).
    Trigger,
}

/// One ANDed term of a compiled program, with its funnel stage, its
/// canonical display key and a deterministic structural cost estimate.
#[derive(Debug, Clone, PartialEq)]
pub struct Conjunct {
    /// Which program bank entry this is.
    pub kind: ConjunctKind,
    /// Funnel stage the conjunct's verdict is recorded under
    /// (0 preselection, 1 objects, 2 event-level, 3 trigger).
    pub stage: u8,
    /// Canonical display string — the profile key.
    pub key: String,
    /// Structural per-event cost estimate (arbitrary units, > 0).
    pub cost: f64,
}

/// Runtime tallies for one conjunct.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ConjunctStats {
    /// Events alive when the conjunct ran.
    pub visited: u64,
    /// Events still alive after it.
    pub passed: u64,
    /// Wall-clock microseconds spent evaluating it (reporting only —
    /// never an input to the ordering).
    pub cost_us: u64,
}

impl ConjunctStats {
    /// Measured pass rate, defaulting to 0.5 before any event was seen
    /// (an uninformative prior that keeps unvisited conjuncts ranked
    /// by cost alone).
    pub fn pass_rate(&self) -> f64 {
        if self.visited == 0 {
            0.5
        } else {
            self.passed as f64 / self.visited as f64
        }
    }

    /// Fold another tally into this one.
    pub fn merge(&mut self, other: &ConjunctStats) {
        self.visited += other.visited;
        self.passed += other.passed;
        self.cost_us += other.cost_us;
    }
}

/// A persistent, mergeable map of conjunct key → tallies: the unit
/// that rides `Timeline → JobReport → JobStatus → wire → HTTP JSON`
/// and persists next to a materialized skim for warm starts.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SelectivityProfile {
    /// Tallies keyed by canonical conjunct display string.
    pub entries: BTreeMap<String, ConjunctStats>,
}

impl SelectivityProfile {
    /// Add tallies for `key` (creating the entry if new).
    pub fn record(&mut self, key: &str, visited: u64, passed: u64, cost_us: u64) {
        let e = self.entries.entry(key.to_string()).or_default();
        e.visited += visited;
        e.passed += passed;
        e.cost_us += cost_us;
    }

    /// Fold `other` into this profile, key by key.
    pub fn merge(&mut self, other: &SelectivityProfile) {
        for (k, s) in &other.entries {
            self.entries.entry(k.clone()).or_default().merge(s);
        }
    }

    /// Tallies for `key`, if any were recorded.
    pub fn get(&self, key: &str) -> Option<&ConjunctStats> {
        self.entries.get(key)
    }

    /// Serialize as one tab-separated line per conjunct
    /// (`visited\tpassed\tcost_us\tkey` — keys never contain tabs).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for (k, s) in &self.entries {
            out.push_str(&format!("{}\t{}\t{}\t{}\n", s.visited, s.passed, s.cost_us, k));
        }
        out
    }

    /// Parse the [`SelectivityProfile::to_text`] format, skipping
    /// malformed lines (a corrupt sidecar degrades to a cold start,
    /// never an error).
    pub fn from_text(text: &str) -> SelectivityProfile {
        let mut p = SelectivityProfile::default();
        for line in text.lines() {
            let mut it = line.splitn(4, '\t');
            let (Some(v), Some(pa), Some(c), Some(key)) =
                (it.next(), it.next(), it.next(), it.next())
            else {
                continue;
            };
            let (Ok(v), Ok(pa), Ok(c)) = (v.parse(), pa.parse(), c.parse()) else {
                continue;
            };
            if key.is_empty() {
                continue;
            }
            p.record(key, v, pa, c);
        }
        p
    }

    /// Is there nothing recorded?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn op_token(op: u8) -> &'static str {
    match op {
        0 => ">",
        1 => ">=",
        2 => "<",
        3 => "<=",
        4 => "==",
        _ => "!=",
    }
}

fn cmp_key(name: &str, op: u8, abs: bool, value: f32) -> String {
    if abs {
        format!("abs({name}) {} {value}", op_token(op))
    } else {
        format!("{name} {} {value}", op_token(op))
    }
}

fn bin_token(op: BinOp) -> &'static str {
    match op {
        BinOp::Add => "+",
        BinOp::Sub => "-",
        BinOp::Mul => "*",
        BinOp::Div => "/",
        BinOp::Lt => "<",
        BinOp::Le => "<=",
        BinOp::Gt => ">",
        BinOp::Ge => ">=",
        BinOp::Eq => "==",
        BinOp::Ne => "!=",
        BinOp::And => "&&",
        BinOp::Or => "||",
        BinOp::Min => "min",
        BinOp::Max => "max",
    }
}

/// Render a compiled residual expression back to a canonical cut-like
/// string with column names resolved (the display key of a
/// [`ConjunctKind::Residual`] conjunct).
fn render_cexpr(e: &CExpr, p: &CutProgram) -> String {
    match e {
        CExpr::Num(v) => format!("{v}"),
        CExpr::Scalar(s) => p.scalar_columns[*s].clone(),
        CExpr::Jagged(c) => p.obj_columns[*c].clone(),
        CExpr::Unary(op, x) => {
            let inner = render_cexpr(x, p);
            match op {
                UnaryOp::Neg => format!("-({inner})"),
                UnaryOp::Not => format!("!({inner})"),
                UnaryOp::Abs => format!("abs({inner})"),
            }
        }
        CExpr::Binary(op, a, b) => {
            let (ra, rb) = (render_cexpr(a, p), render_cexpr(b, p));
            match op {
                BinOp::Min | BinOp::Max => format!("{}({ra}, {rb})", bin_token(*op)),
                _ => format!("({ra} {} {rb})", bin_token(*op)),
            }
        }
        CExpr::Agg { op, arg, pred, .. } => {
            let name = match op {
                AggOp::Count => "count",
                AggOp::Any => "any",
                AggOp::All => "all",
                AggOp::Sum => "sum",
                AggOp::Max => "max",
                AggOp::Min => "min",
            };
            match pred {
                Some(pr) => {
                    format!("{name}({}[{}])", render_cexpr(arg, p), render_cexpr(pr, p))
                }
                None => format!("{name}({})", render_cexpr(arg, p)),
            }
        }
        CExpr::Shared(x) => render_cexpr(x, p),
    }
}

/// Structural per-evaluation cost of a residual expression: node count
/// with object-shaped work (aggregation slot loops) weighted ×4, and
/// shared subtrees counted as a cached read.
fn cexpr_cost(e: &CExpr) -> f64 {
    match e {
        CExpr::Num(_) | CExpr::Scalar(_) | CExpr::Jagged(_) => 1.0,
        CExpr::Unary(_, x) => 1.0 + cexpr_cost(x),
        CExpr::Binary(_, a, b) => 1.0 + cexpr_cost(a) + cexpr_cost(b),
        CExpr::Agg { arg, pred, .. } => {
            let inner = cexpr_cost(arg) + pred.as_ref().map_or(0.0, |p| cexpr_cost(p));
            2.0 + 4.0 * inner
        }
        // Evaluated once, then read from the scratch column.
        CExpr::Shared(_) => 1.0,
    }
}

/// Enumerate the ANDed conjuncts of a compiled program in its fixed
/// (stage) evaluation order, with canonical keys and structural cost
/// estimates. This is the identity the adaptive evaluator permutes and
/// the profile is keyed by.
pub fn conjuncts_of(program: &CutProgram) -> Vec<Conjunct> {
    let mut out = Vec::new();
    for (i, c) in program.scalar_cuts.iter().enumerate() {
        out.push(Conjunct {
            kind: ConjunctKind::Scalar(i),
            stage: 0,
            key: cmp_key(&program.scalar_columns[c.col], c.op, c.abs, c.value),
            cost: 1.0,
        });
    }
    for (i, g) in program.groups.iter().enumerate() {
        let cuts: Vec<String> = program.obj_cuts[g.cut_range.clone()]
            .iter()
            .map(|c| cmp_key(&program.obj_columns[c.col], c.op, c.abs, c.value))
            .collect();
        out.push(Conjunct {
            kind: ConjunctKind::Group(i),
            stage: 1,
            key: format!("count({}) >= {}", cuts.join(" && "), g.min_count),
            cost: 2.0 + 4.0 * g.cut_range.len() as f64,
        });
    }
    if let Some(ht) = &program.ht {
        let col = &program.obj_columns[ht.col];
        out.push(Conjunct {
            kind: ConjunctKind::Ht,
            stage: 2,
            key: format!("sum({col}[{col} > {}]) >= {}", ht.object_pt_min, ht.min_ht),
            cost: 6.0,
        });
    }
    for (i, e) in program.exprs.iter().enumerate() {
        out.push(Conjunct {
            kind: ConjunctKind::Residual(i),
            stage: 2,
            key: render_cexpr(e, program),
            cost: cexpr_cost(e),
        });
    }
    if !program.triggers.is_empty() {
        let flags: Vec<&str> =
            program.triggers.iter().map(|&s| program.scalar_columns[s].as_str()).collect();
        out.push(Conjunct {
            kind: ConjunctKind::Trigger,
            stage: 3,
            key: format!("trigger({})", flags.join(" | ")),
            cost: program.triggers.len() as f64,
        });
    }
    out
}

/// The adaptive ordering: indices into `conjuncts` sorted by
/// `cost / (1 - pass_rate)` ascending — cheapest, most selective
/// first. A conjunct that has never killed an event (pass rate ≥ 1)
/// ranks infinite and runs last; ties (including all-infinite, the
/// pathological all-pass case) break on the original index, so the
/// fixed stage order is the deterministic fallback.
pub fn rank_order(conjuncts: &[Conjunct], stats: &[ConjunctStats]) -> Vec<usize> {
    debug_assert_eq!(conjuncts.len(), stats.len());
    let rank = |i: usize| -> f64 {
        let kill = 1.0 - stats[i].pass_rate();
        if kill <= 0.0 {
            f64::INFINITY
        } else {
            conjuncts[i].cost / kill
        }
    };
    let mut idx: Vec<usize> = (0..conjuncts.len()).collect();
    idx.sort_by(|&a, &b| rank(a).partial_cmp(&rank(b)).unwrap().then(a.cmp(&b)));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::plan::{HtParam, ObjCutParam, ObjGroup, ScalarCutParam};

    fn program() -> CutProgram {
        let mut p = CutProgram::default();
        p.scalar_columns = vec!["MET_pt".into(), "HLT_IsoMu24".into()];
        p.obj_columns = vec!["Electron_pt".into(), "Jet_pt".into()];
        p.scalar_cuts.push(ScalarCutParam { col: 0, op: 0, abs: false, value: 25.0 });
        p.obj_cuts.push(ObjCutParam { col: 0, op: 0, abs: false, value: 25.0 });
        p.groups.push(ObjGroup { collection: "Electron".into(), cut_range: 0..1, min_count: 1 });
        p.ht = Some(HtParam { col: 1, object_pt_min: 30.0, min_ht: 200.0 });
        p.triggers.push(1);
        p.exprs.push(CExpr::Binary(
            BinOp::Gt,
            Box::new(CExpr::Scalar(0)),
            Box::new(CExpr::Num(100.0)),
        ));
        p
    }

    #[test]
    fn conjunct_keys_are_canonical_displays() {
        let cs = conjuncts_of(&program());
        let keys: Vec<&str> = cs.iter().map(|c| c.key.as_str()).collect();
        assert_eq!(
            keys,
            vec![
                "MET_pt > 25",
                "count(Electron_pt > 25) >= 1",
                "sum(Jet_pt[Jet_pt > 30]) >= 200",
                "(MET_pt > 100)",
                "trigger(HLT_IsoMu24)",
            ]
        );
        assert_eq!(cs.iter().map(|c| c.stage).collect::<Vec<_>>(), vec![0, 1, 2, 2, 3]);
        assert!(cs.iter().all(|c| c.cost > 0.0));
    }

    #[test]
    fn rank_prefers_cheap_selective_conjuncts() {
        let cs = conjuncts_of(&program());
        let mut stats = vec![ConjunctStats::default(); cs.len()];
        // Unvisited: rank = cost / 0.5 — pure cost order (scalar cut
        // and trigger tie at cost 1, index breaks the tie).
        assert_eq!(rank_order(&cs, &stats), vec![0, 4, 1, 3, 2]);

        // The HT unit measured maximally selective: it jumps first
        // despite its cost; the all-pass scalar cut drops last.
        stats[2] = ConjunctStats { visited: 1000, passed: 10, cost_us: 5 };
        stats[0] = ConjunctStats { visited: 1000, passed: 1000, cost_us: 1 };
        let order = rank_order(&cs, &stats);
        assert_eq!(order[0], 2);
        assert_eq!(*order.last().unwrap(), 0);
    }

    #[test]
    fn all_pass_stats_fall_back_to_fixed_order() {
        let cs = conjuncts_of(&program());
        let stats: Vec<ConjunctStats> = cs
            .iter()
            .map(|_| ConjunctStats { visited: 500, passed: 500, cost_us: 1 })
            .collect();
        // Every rank is infinite — the tie-break keeps stage order.
        assert_eq!(rank_order(&cs, &stats), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn profile_round_trips_through_text() {
        let mut p = SelectivityProfile::default();
        p.record("MET_pt > 25", 1000, 400, 37);
        p.record("trigger(HLT_IsoMu24 | HLT_Ele32_WPTight)", 400, 390, 12);
        let text = p.to_text();
        assert_eq!(SelectivityProfile::from_text(&text), p);
        // Malformed lines are skipped, not fatal.
        let dirty = format!("garbage\n{text}also\tbad\n");
        assert_eq!(SelectivityProfile::from_text(&dirty), p);
        // Merge accumulates key-wise.
        let mut q = p.clone();
        q.merge(&p);
        assert_eq!(q.get("MET_pt > 25").unwrap().visited, 2000);
        assert_eq!(q.get("MET_pt > 25").unwrap().passed, 800);
    }

    #[test]
    fn shared_subtrees_render_transparently_and_cost_as_reads() {
        let inner = CExpr::Binary(
            BinOp::Mul,
            Box::new(CExpr::Scalar(0)),
            Box::new(CExpr::Num(2.0)),
        );
        let shared = CExpr::Shared(std::sync::Arc::new(inner.clone()));
        let mut p = CutProgram::default();
        p.scalar_columns.push("MET_pt".into());
        assert_eq!(render_cexpr(&shared, &p), render_cexpr(&inner, &p));
        assert!(cexpr_cost(&shared) < cexpr_cost(&inner));
    }
}
