//! Profile-guided kernel fusion planning (ROADMAP "engine
//! micro-optimizations").
//!
//! The adaptive evaluator ([`crate::engine::interp::eval_adaptive`])
//! runs one sweep over the alive set **per conjunct** — each sweep
//! re-walks the alive bookkeeping and re-touches the batch. For the
//! shapes that dominate real skims (scalar compares, single-cut object
//! counts, the HT sum) that per-conjunct overhead is most of the work.
//! This module plans which conjuncts to **fuse** into the specialized
//! kernels of [`crate::engine::fused`]:
//!
//! * `cmp` — one scalar compare, swept branch-free over 64-event words;
//! * `range` — two compares on the same column forming `lo ≤ x < hi`;
//! * `and-chain` — 2–3 scalar compares evaluated together per word, one
//!   alive-set pass for the whole run;
//! * `count` — a single-cut object group, `count(pred) ≥ k`, counted
//!   branchless over the valid slot prefix;
//! * `sum` — the HT unit, `sum(x[x > pt_min]) ≥ t`, accumulated
//!   branchless.
//!
//! Planning is **profile-guided**: the same [`ConjunctStats`] that
//! drive [`rank_order`](crate::query::stats::rank_order) decide what is
//! worth fusing. A conjunct fuses only when its shape matches a kernel,
//! it is ranked in the **leading half** of the evaluation order (late
//! conjuncts see few survivors — the interpreter's per-event walk is
//! already cheap there), and its measured pass rate is below ~1 (an
//! all-pass conjunct kills nothing; fusing it buys nothing). Everything
//! else falls back to the interpreter's per-conjunct `eval_conjunct`
//! sweep, unfused and untouched.
//!
//! The plan is a straight-line program over the evaluation order
//! ([`FuseStep`]s), rebuilt whenever the adaptive executor replans, and
//! every decision carries a human-readable reason — surfaced verbatim
//! by `skimroot skim --explain --fuse`.

use crate::query::plan::CutProgram;
use crate::query::stats::{Conjunct, ConjunctKind, ConjunctStats};

/// Longest scalar-compare run a single [`FusedKernel::Chain`] covers.
/// Beyond three predicates the per-word passmasks stop fitting in
/// registers and the fused sweep loses to two shorter chains.
pub const MAX_CHAIN: usize = 3;

/// Pass rate at or above which a conjunct is treated as all-pass and
/// left to the interpreter (it kills nothing, so a fused sweep saves
/// nothing; the rank already pushes it last).
pub const ALL_PASS_RATE: f64 = 0.999;

/// One scalar compare folded into a [`FusedKernel::Chain`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChainLink {
    /// Conjunct index (into the planner's conjunct list) this link
    /// settles — tallies and funnel-stage rows are attributed here.
    pub ci: usize,
    /// Index into [`CutProgram::scalar_cuts`].
    pub cut: usize,
}

/// One fused kernel: a shape the engine evaluates in a single pass
/// over the alive set instead of one interpreter sweep per conjunct.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FusedKernel {
    /// 1–[`MAX_CHAIN`] scalar compares evaluated together per 64-event
    /// word (covers the `cmp`, `range` and `and-chain` labels).
    Chain(Vec<ChainLink>),
    /// Single-cut object group: `count(pred over slots) >= min_count`.
    CountGe {
        /// Conjunct index the verdict is attributed to.
        ci: usize,
        /// Index into [`CutProgram::groups`].
        group: usize,
    },
    /// The HT unit: `sum(x[x > pt_min]) >= min_ht`.
    SumGe {
        /// Conjunct index the verdict is attributed to.
        ci: usize,
    },
}

impl FusedKernel {
    /// How many consecutive evaluation-order positions the kernel
    /// consumes.
    pub fn span(&self) -> usize {
        match self {
            FusedKernel::Chain(links) => links.len(),
            _ => 1,
        }
    }
}

/// One step of the fused evaluation program, in evaluation order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FuseStep {
    /// Run a fused kernel (consumes [`FusedKernel::span`] conjuncts).
    Kernel(FusedKernel),
    /// Evaluate conjunct `ci` with the interpreter's per-conjunct
    /// sweep — the untouched fallback.
    Interp(usize),
}

/// Why one conjunct did or did not fuse — the `--explain --fuse` row.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FuseDecision {
    /// Canonical conjunct key ([`Conjunct::key`]).
    pub key: String,
    /// Kernel label (`"cmp"`, `"range"`, `"and-chain"`, `"count"`,
    /// `"sum"`) when fused; `None` when left to the interpreter.
    pub fused: Option<&'static str>,
    /// Human-readable rationale for the decision.
    pub reason: String,
}

/// A complete fusion plan over one compiled program: the straight-line
/// [`FuseStep`] program the fused evaluator walks, plus one
/// [`FuseDecision`] per conjunct (indexed like the conjunct list) and
/// the evaluation order it was planned for.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FusePlan {
    /// Steps in evaluation order; every conjunct appears exactly once
    /// (inside a kernel or as an `Interp` fallback).
    pub steps: Vec<FuseStep>,
    /// Per-conjunct decisions, indexed by conjunct index.
    pub decisions: Vec<FuseDecision>,
    /// The evaluation order the plan was built against.
    pub order: Vec<usize>,
}

impl FusePlan {
    /// Number of conjuncts that fused into a kernel.
    pub fn fused_count(&self) -> usize {
        self.decisions.iter().filter(|d| d.fused.is_some()).count()
    }

    /// Did anything fuse at all? (If not, the engine skips the fused
    /// evaluator entirely.)
    pub fn any_fused(&self) -> bool {
        self.decisions.iter().any(|d| d.fused.is_some())
    }

    /// Render the plan as the `--explain --fuse` table: one row per
    /// conjunct in evaluation order, kernel label or `interp`, and the
    /// reason.
    pub fn describe(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "fusion plan: {} of {} conjuncts fused\n",
            self.fused_count(),
            self.decisions.len()
        ));
        for &ci in &self.order {
            let d = &self.decisions[ci];
            let label = d.fused.unwrap_or("interp");
            out.push_str(&format!("  [{label:9}] {}  — {}\n", d.key, d.reason));
        }
        out
    }
}

/// Shape eligibility of one conjunct, before position/profile checks.
enum Shape {
    ScalarCmp(usize),
    CountGe(usize),
    SumGe,
}

fn shape_of(program: &CutProgram, kind: ConjunctKind) -> Result<Shape, &'static str> {
    match kind {
        ConjunctKind::Scalar(i) => Ok(Shape::ScalarCmp(i)),
        ConjunctKind::Group(i) if program.groups[i].cut_range.len() == 1 => {
            Ok(Shape::CountGe(i))
        }
        ConjunctKind::Group(_) => Err("multi-cut object group: interpreter only"),
        ConjunctKind::Ht => Ok(Shape::SumGe),
        ConjunctKind::Residual(_) => Err("residual expression: interpreter only"),
        ConjunctKind::Trigger => Err("trigger OR: interpreter only"),
    }
}

/// Do two scalar cuts form a `lo ≤ x < hi` band on one column? (Same
/// column, neither under `abs`, one lower bound `>`/`>=` and one upper
/// bound `<`/`<=` — in either order.)
fn is_range_pair(program: &CutProgram, a: usize, b: usize) -> bool {
    let (ca, cb) = (&program.scalar_cuts[a], &program.scalar_cuts[b]);
    let lower = |op: u8| op == 0 || op == 1;
    let upper = |op: u8| op == 2 || op == 3;
    ca.col == cb.col
        && !ca.abs
        && !cb.abs
        && ((lower(ca.op) && upper(cb.op)) || (upper(ca.op) && lower(cb.op)))
}

/// Plan kernel fusion for `program` under the given evaluation `order`
/// and the profile in `stats` (parallel to `conjuncts`). Deterministic
/// in its inputs: the same program + order + tallies always produce the
/// same plan, so fused runs stay reproducible.
pub fn fuse_plan(
    program: &CutProgram,
    conjuncts: &[Conjunct],
    order: &[usize],
    stats: &[ConjunctStats],
) -> FusePlan {
    debug_assert_eq!(conjuncts.len(), stats.len());
    debug_assert_eq!(conjuncts.len(), order.len());
    let n = conjuncts.len();

    // Pass 1: per-conjunct eligibility (shape, rank position, profile),
    // recorded by evaluation-order position.
    let mut eligible: Vec<Option<Shape>> = Vec::with_capacity(n);
    let mut decisions: Vec<FuseDecision> = conjuncts
        .iter()
        .map(|c| FuseDecision { key: c.key.clone(), fused: None, reason: String::new() })
        .collect();
    for (pos, &ci) in order.iter().enumerate() {
        let verdict = match shape_of(program, conjuncts[ci].kind) {
            Err(msg) => Err(msg.to_string()),
            Ok(_) if n > 2 && pos * 2 >= n => {
                Err(format!("ranked late (position {} of {n}): survivors are few", pos + 1))
            }
            Ok(_) if stats[ci].visited > 0 && stats[ci].pass_rate() >= ALL_PASS_RATE => {
                Err("profile shows all-pass: fusing saves nothing".to_string())
            }
            Ok(shape) => Ok(shape),
        };
        match verdict {
            Ok(shape) => eligible.push(Some(shape)),
            Err(reason) => {
                decisions[ci].reason = reason;
                eligible.push(None);
            }
        }
    }

    // Pass 2: walk the order, folding maximal runs of eligible scalar
    // compares into chains and wrapping eligible count/sum conjuncts
    // as single-step kernels.
    let mut steps = Vec::new();
    let mut pos = 0usize;
    while pos < n {
        let ci = order[pos];
        match &eligible[pos] {
            Some(Shape::ScalarCmp(_)) => {
                // Collect the maximal consecutive run of eligible
                // scalar compares starting here.
                let mut run: Vec<ChainLink> = Vec::new();
                while pos < n {
                    match eligible[pos] {
                        Some(Shape::ScalarCmp(cut)) => {
                            run.push(ChainLink { ci: order[pos], cut });
                            pos += 1;
                        }
                        _ => break,
                    }
                }
                for chunk in run.chunks(MAX_CHAIN) {
                    let label = match chunk {
                        [_] => "cmp",
                        [a, b] if is_range_pair(program, a.cut, b.cut) => "range",
                        _ => "and-chain",
                    };
                    for link in chunk {
                        decisions[link.ci].fused = Some(label);
                        decisions[link.ci].reason = match chunk.len() {
                            1 => "hot scalar compare".to_string(),
                            _ => format!(
                                "hot scalar compare, fused with {} neighbor(s)",
                                chunk.len() - 1
                            ),
                        };
                    }
                    steps.push(FuseStep::Kernel(FusedKernel::Chain(chunk.to_vec())));
                }
            }
            Some(Shape::CountGe(group)) => {
                decisions[ci].fused = Some("count");
                decisions[ci].reason = "single-cut object group: branchless count".to_string();
                steps.push(FuseStep::Kernel(FusedKernel::CountGe { ci, group: *group }));
                pos += 1;
            }
            Some(Shape::SumGe) => {
                decisions[ci].fused = Some("sum");
                decisions[ci].reason = "HT sum: branchless accumulate".to_string();
                steps.push(FuseStep::Kernel(FusedKernel::SumGe { ci }));
                pos += 1;
            }
            None => {
                steps.push(FuseStep::Interp(ci));
                pos += 1;
            }
        }
    }

    FusePlan { steps, decisions, order: order.to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::plan::{HtParam, ObjCutParam, ObjGroup, ScalarCutParam};
    use crate::query::stats::conjuncts_of;

    fn cut(col: usize, op: u8, value: f32) -> ScalarCutParam {
        ScalarCutParam { col, op, abs: false, value }
    }

    /// MET_pt > 25 && 20 <= Eta < 40 && count(Electron_pt > 25) >= 1
    /// && HT && residual && trigger — a bit of every shape.
    fn program() -> CutProgram {
        let mut p = CutProgram::default();
        p.scalar_columns =
            vec!["MET_pt".into(), "Eta".into(), "HLT_IsoMu24".into()];
        p.obj_columns = vec!["Electron_pt".into(), "Jet_pt".into()];
        p.scalar_cuts.push(cut(0, 0, 25.0));
        p.scalar_cuts.push(cut(1, 1, 20.0));
        p.scalar_cuts.push(cut(1, 2, 40.0));
        p.obj_cuts.push(ObjCutParam { col: 0, op: 0, abs: false, value: 25.0 });
        p.groups.push(ObjGroup {
            collection: "Electron".into(),
            cut_range: 0..1,
            min_count: 1,
        });
        p.ht = Some(HtParam { col: 1, object_pt_min: 30.0, min_ht: 200.0 });
        p.triggers.push(2);
        p
    }

    fn identity_plan(p: &CutProgram) -> FusePlan {
        let cs = conjuncts_of(p);
        let order: Vec<usize> = (0..cs.len()).collect();
        let stats = vec![ConjunctStats::default(); cs.len()];
        fuse_plan(p, &cs, &order, &stats)
    }

    #[test]
    fn chains_count_and_sum_fuse_trigger_stays_interpreted() {
        let p = program();
        let plan = identity_plan(&p);
        // Conjuncts: 3 scalars, 1 group, 1 ht, trigger = 6; leading
        // half = positions 0..2, so the scalar run (positions 0-2)
        // fuses but only the first three positions pass the rank gate.
        assert_eq!(plan.decisions.len(), 6);
        assert_eq!(plan.decisions[0].fused, Some("and-chain"));
        assert_eq!(plan.decisions[1].fused, Some("and-chain"));
        assert_eq!(plan.decisions[2].fused, Some("and-chain"));
        assert_eq!(plan.decisions[3].fused, None, "group ranked late");
        assert!(plan.decisions[3].reason.contains("ranked late"));
        assert_eq!(plan.decisions[5].fused, None);
        assert!(plan.decisions[5].reason.contains("trigger OR"));
        // Steps cover every conjunct exactly once.
        let covered: usize = plan
            .steps
            .iter()
            .map(|s| match s {
                FuseStep::Kernel(k) => k.span(),
                FuseStep::Interp(_) => 1,
            })
            .sum();
        assert_eq!(covered, 6);
        assert!(plan.any_fused());
    }

    #[test]
    fn range_pair_is_detected_and_single_cut_is_cmp() {
        // Only the band on Eta, reordered so the pair is adjacent and
        // leading: [eta >= 20, eta < 40, met > 25] — the pair fuses as
        // a range, the met cut (position 3 of 3 is past the leading
        // half) stays interpreted.
        let p = program();
        let cs: Vec<Conjunct> =
            conjuncts_of(&p).into_iter().take(3).collect();
        let order = vec![1, 2, 0];
        let stats = vec![ConjunctStats::default(); 3];
        let plan = fuse_plan(&p, &cs, &order, &stats);
        assert_eq!(plan.decisions[1].fused, Some("range"));
        assert_eq!(plan.decisions[2].fused, Some("range"));
        assert_eq!(plan.decisions[0].fused, None);

        // A lone leading compare is a plain cmp kernel.
        let mut solo = p.clone();
        solo.scalar_cuts.truncate(1);
        let solo_cs = conjuncts_of(&solo);
        let solo_order: Vec<usize> = (0..solo_cs.len()).collect();
        let solo_stats = vec![ConjunctStats::default(); solo_cs.len()];
        let plan = fuse_plan(&solo, &solo_cs, &solo_order, &solo_stats);
        assert_eq!(plan.decisions[0].fused, Some("cmp"));
    }

    #[test]
    fn all_pass_profile_blocks_fusion() {
        let p = program();
        let cs = conjuncts_of(&p);
        let order: Vec<usize> = (0..cs.len()).collect();
        let mut stats = vec![ConjunctStats::default(); cs.len()];
        stats[0] = ConjunctStats { visited: 1000, passed: 1000, cost_us: 3 };
        let plan = fuse_plan(&p, &cs, &order, &stats);
        assert_eq!(plan.decisions[0].fused, None);
        assert!(plan.decisions[0].reason.contains("all-pass"));
        // The neighbors still chain without it.
        assert_eq!(plan.decisions[1].fused, Some("range"));
        assert_eq!(plan.decisions[2].fused, Some("range"));
    }

    #[test]
    fn tiny_programs_skip_the_rank_gate() {
        // n <= 2: everything eligible fuses regardless of position.
        let mut p = CutProgram::default();
        p.scalar_columns = vec!["a".into(), "b".into()];
        p.scalar_cuts.push(cut(0, 0, 1.0));
        p.scalar_cuts.push(cut(1, 2, 5.0));
        let plan = identity_plan(&p);
        assert_eq!(plan.fused_count(), 2);
    }

    #[test]
    fn long_runs_chunk_at_max_chain() {
        let mut p = CutProgram::default();
        p.scalar_columns = (0..8).map(|i| format!("c{i}")).collect();
        for i in 0..8 {
            p.scalar_cuts.push(cut(i, 0, i as f32));
        }
        let plan = identity_plan(&p);
        // Leading half of 8 = positions 0..3, wait: pos*2 < 8 →
        // positions 0..=3 fuse; run of 4 chunks as 3 + 1.
        let kernels: Vec<usize> = plan
            .steps
            .iter()
            .filter_map(|s| match s {
                FuseStep::Kernel(k) => Some(k.span()),
                _ => None,
            })
            .collect();
        assert_eq!(kernels, vec![3, 1]);
        assert_eq!(plan.fused_count(), 4);
        assert_eq!(plan.decisions[3].fused, Some("cmp"));
        assert!(plan.decisions[4].reason.contains("ranked late"));
    }

    #[test]
    fn describe_lists_every_conjunct_with_reasons() {
        let p = program();
        let plan = identity_plan(&p);
        let text = plan.describe();
        assert!(text.contains("fusion plan: 3 of 6 conjuncts fused"), "{text}");
        for d in &plan.decisions {
            assert!(text.contains(&d.key), "missing {} in {text}", d.key);
        }
        assert!(text.contains("[interp"));
        assert!(text.contains("[and-chain]"));
    }
}
