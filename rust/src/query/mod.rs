//! The JSON query front-end (§3.1) — SkimROOT's replacement for
//! hand-written ROOT C++ filtering scripts.
//!
//! * [`json`] — hand-rolled JSON parser/serializer (no serde offline);
//! * [`ast`] — the query schema: input/output, branch patterns,
//!   `force_all`, and the multi-stage selection (preselection →
//!   object-level → event-level), mirroring Figure 2c;
//! * [`wildcard`] — glob expansion of branch patterns against the file
//!   schema, including the curated `HLT_*` → minimal-trigger-set
//!   mapping with missing-branch warnings;
//! * [`plan`] — query + file schema → [`plan::SkimPlan`]: the
//!   criteria/output-only branch split that drives two-phase execution,
//!   and the numeric [`plan::CutProgram`] consumed by both the scalar
//!   interpreter and the AOT-compiled vectorized kernel.

pub mod ast;
pub mod json;
pub mod plan;
pub mod wildcard;

pub use ast::{CmpOp, EventSelection, ObjectCut, ObjectSelection, ScalarCut, Selection, SkimQuery};
pub use json::Json;
pub use plan::{CutProgram, SkimPlan};
