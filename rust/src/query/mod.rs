//! The query front-end (§3.1) — SkimROOT's replacement for
//! hand-written ROOT C++ filtering scripts, layered over an open
//! expression IR.
//!
//! * [`expr`] — **Layer 0**: the typed [`Expr`] AST (literals, branch
//!   refs, arithmetic, comparisons, boolean structure, aggregations)
//!   that every frontend lowers to;
//! * [`parse`] — the TCut-style cut-string frontend
//!   (`"nMuon >= 2 && (HLT_Mu50 || max(Muon_pt) > 100)"`);
//! * [`json`] — hand-rolled JSON parser/serializer (no serde offline);
//! * [`ast`] — the query schema: input dataset/output, branch
//!   patterns, `force_all`, the Figure-2c structured selection (now
//!   sugar that lowers onto the IR) and the free-form `"cut"` field;
//! * [`dataset`] — the [`DatasetSpec`] input unit: one file, an
//!   explicit list, a glob over the storage export, or a named
//!   catalog (resolution lives in [`crate::catalog`]);
//! * [`wildcard`] — glob expansion of branch patterns against the file
//!   schema, including the curated `HLT_*` → minimal-trigger-set
//!   mapping with missing-branch warnings;
//! * [`plan`] — query + file schema → [`plan::SkimPlan`]: the
//!   criteria/output-only branch split that drives two-phase execution,
//!   and the numeric [`plan::CutProgram`] consumed by both the scalar
//!   interpreter and the AOT-compiled vectorized kernel. IR conjuncts
//!   that match the kernel's fixed-function stages are classified onto
//!   them; the rest compile to residual [`plan::CExpr`]s that keep
//!   [`plan::CutProgram::fits_kernel`] honest;
//! * [`stats`] — per-conjunct selectivity statistics and the
//!   cost-over-kill-rate ranking behind selectivity-adaptive
//!   execution, plus the persistent [`stats::SelectivityProfile`];
//! * [`fuse`] — profile-guided kernel-fusion planning: which conjuncts
//!   collapse into the fused sweeps of [`crate::engine::fused`], and
//!   why the rest stay on the interpreter.

pub mod ast;
pub mod dataset;
pub mod expr;
pub mod fuse;
pub mod json;
pub mod parse;
pub mod plan;
pub mod stats;
pub mod wildcard;

pub use ast::{CmpOp, EventSelection, ObjectCut, ObjectSelection, ScalarCut, Selection, SkimQuery};
pub use dataset::DatasetSpec;
pub use expr::{AggOp, BinOp, Expr, UnaryOp};
pub use fuse::{FuseDecision, FusePlan};
pub use json::Json;
pub use parse::parse_cut;
pub use plan::{CutProgram, SkimPlan, ZoneCmp, ZonePredicate};
pub use stats::{Conjunct, ConjunctKind, ConjunctStats, SelectivityProfile};
