//! Branch pattern expansion + the curated `HLT_*` optimization (§3.1).
//!
//! Users select output branches with glob patterns (`Electron_*`,
//! `HLT_*`). The paper observes that `HLT_*` expands to 650+ trigger
//! flags while analyses typically use fewer than 23 — so SkimROOT maps
//! broad trigger wildcards to a curated minimal set (based on usage
//! statistics), logging a warning with the count of excluded branches.
//! `"force_all": true` disables the mapping.

/// The curated trigger set: the paper's "fewer than 23 specific
/// triggers" that CMS analyses actually read. (Representative Run-3
/// single-lepton / MET / jet paths.) This is what a broad `HLT_*`
/// wildcard maps to unless `force_all` is set.
pub const CURATED_TRIGGERS: [&str; 23] = [
    "HLT_IsoMu24",
    "HLT_IsoMu27",
    "HLT_Mu50",
    "HLT_Ele27_WPTight",
    "HLT_Ele32_WPTight",
    "HLT_Ele35_WPTight",
    "HLT_Photon200",
    "HLT_PFMET120_PFMHT120",
    "HLT_PFMETNoMu120_PFMHTNoMu120",
    "HLT_PFHT1050",
    "HLT_PFJet500",
    "HLT_AK8PFJet400_TrimMass30",
    "HLT_DoubleEle25_CaloIdL_MW",
    "HLT_Mu17_TrkIsoVVL_Mu8_TrkIsoVVL_DZ_Mass3p8",
    "HLT_Mu23_TrkIsoVVL_Ele12_CaloIdL_TrackIdL_IsoVL",
    "HLT_Mu8_TrkIsoVVL_Ele23_CaloIdL_TrackIdL_IsoVL_DZ",
    "HLT_DoublePFJets40_CaloBTagDeepCSV",
    "HLT_QuadPFJet70_50_40_30",
    "HLT_TripleMu_12_10_5",
    "HLT_BTagMu_AK4DiJet40_Mu5",
    "HLT_MET105_IsoTrk50",
    "HLT_TkMu100",
    "HLT_OldMu100",
];

/// Glob match: `*` = any run (incl. empty), `?` = one character.
/// Iterative two-pointer algorithm — no recursion, no blowup.
pub fn glob_match(pattern: &str, name: &str) -> bool {
    let p: Vec<char> = pattern.chars().collect();
    let n: Vec<char> = name.chars().collect();
    let (mut pi, mut ni) = (0usize, 0usize);
    let mut star: Option<(usize, usize)> = None; // (pattern idx after *, name idx)
    while ni < n.len() {
        if pi < p.len() && (p[pi] == '?' || p[pi] == n[ni]) {
            pi += 1;
            ni += 1;
        } else if pi < p.len() && p[pi] == '*' {
            star = Some((pi + 1, ni));
            pi += 1;
        } else if let Some((sp, sn)) = star {
            pi = sp;
            ni = sn + 1;
            star = Some((sp, sn + 1));
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == '*' {
        pi += 1;
    }
    pi == p.len()
}

/// Is a pattern a "broad trigger wildcard" that the curated mapping
/// applies to? (`HLT_*` and equally-broad prefixes like `HLT_*Mu*`.)
fn is_broad_hlt(pattern: &str) -> bool {
    pattern.starts_with("HLT_") && pattern.contains('*')
}

/// Result of expanding a query's branch patterns against a schema.
#[derive(Debug, Clone, PartialEq)]
pub struct Expansion {
    /// Branch names to keep in the output, in schema order.
    pub selected: Vec<String>,
    /// Human-readable warnings (curated-set exclusions, unmatched
    /// patterns) — the §3.1 "logs a warning for any missing branches".
    pub warnings: Vec<String>,
}

/// Expand `patterns` against `schema` (the file's branch names).
///
/// With `force_all == false`, broad `HLT_*` wildcards are mapped to the
/// intersection of [`CURATED_TRIGGERS`] with the schema; the number of
/// branches excluded by the optimization is reported as a warning.
pub fn expand(patterns: &[String], schema: &[&str], force_all: bool) -> Expansion {
    let mut keep = vec![false; schema.len()];
    let mut warnings = Vec::new();

    for pat in patterns {
        let mut matched = 0usize;
        if !force_all && is_broad_hlt(pat) {
            // Curated mapping: only usage-backed triggers survive.
            let full_count = schema.iter().filter(|n| glob_match(pat, n)).count();
            for (i, name) in schema.iter().enumerate() {
                if glob_match(pat, name) && CURATED_TRIGGERS.contains(name) {
                    keep[i] = true;
                    matched += 1;
                }
            }
            if full_count > matched {
                warnings.push(format!(
                    "pattern '{pat}': curated trigger mapping kept {matched} of {full_count} \
                     matching branches ({} excluded; set \"force_all\": true to keep all)",
                    full_count - matched
                ));
            }
        } else {
            for (i, name) in schema.iter().enumerate() {
                if glob_match(pat, name) {
                    keep[i] = true;
                    matched += 1;
                }
            }
        }
        if matched == 0 {
            warnings.push(format!("pattern '{pat}' matched no branches"));
        }
    }

    let selected = schema
        .iter()
        .zip(&keep)
        .filter(|(_, &k)| k)
        .map(|(n, _)| n.to_string())
        .collect();
    Expansion { selected, warnings }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glob_basics() {
        assert!(glob_match("Electron_*", "Electron_pt"));
        assert!(glob_match("Electron_*", "Electron_"));
        assert!(!glob_match("Electron_*", "Muon_pt"));
        assert!(glob_match("*", "anything"));
        assert!(glob_match("*_pt", "Jet_pt"));
        assert!(glob_match("J?t_pt", "Jet_pt"));
        assert!(!glob_match("J?t_pt", "Jett_pt"));
        assert!(glob_match("*Mu*", "HLT_IsoMu24"));
        assert!(glob_match("exact", "exact"));
        assert!(!glob_match("exact", "exactly"));
        assert!(glob_match("a*b*c", "aXXbYYc"));
        assert!(!glob_match("a*b*c", "aXXbYY"));
        assert!(glob_match("", ""));
        assert!(!glob_match("", "x"));
        assert!(glob_match("**", "x"));
    }

    fn schema() -> Vec<&'static str> {
        vec![
            "nElectron",
            "Electron_pt",
            "Electron_eta",
            "Muon_pt",
            "Jet_pt",
            "MET_pt",
            "HLT_IsoMu24",
            "HLT_Ele32_WPTight",
            "HLT_Obscure_Path_v3",
            "HLT_AnotherRare_v7",
        ]
    }

    #[test]
    fn plain_patterns_expand() {
        let e = expand(
            &["Electron_*".to_string(), "MET_pt".to_string()],
            &schema(),
            false,
        );
        assert_eq!(e.selected, vec!["Electron_pt", "Electron_eta", "MET_pt"]);
        assert!(e.warnings.is_empty());
    }

    #[test]
    fn curated_hlt_mapping() {
        let e = expand(&["HLT_*".to_string()], &schema(), false);
        // Only the curated triggers present in the schema survive.
        assert_eq!(e.selected, vec!["HLT_IsoMu24", "HLT_Ele32_WPTight"]);
        assert_eq!(e.warnings.len(), 1);
        assert!(e.warnings[0].contains("2 excluded"), "{}", e.warnings[0]);
    }

    #[test]
    fn force_all_keeps_everything() {
        let e = expand(&["HLT_*".to_string()], &schema(), true);
        assert_eq!(e.selected.len(), 4);
        assert!(e.warnings.is_empty());
    }

    #[test]
    fn unmatched_pattern_warns() {
        let e = expand(&["Tau_*".to_string()], &schema(), false);
        assert!(e.selected.is_empty());
        assert_eq!(e.warnings.len(), 1);
        assert!(e.warnings[0].contains("matched no branches"));
    }

    #[test]
    fn order_is_schema_order_and_deduplicated() {
        let e = expand(
            &["*_pt".to_string(), "Electron_*".to_string()],
            &schema(),
            false,
        );
        assert_eq!(
            e.selected,
            vec!["Electron_pt", "Electron_eta", "Muon_pt", "Jet_pt", "MET_pt"]
        );
    }

    #[test]
    fn curated_list_size_matches_paper() {
        assert_eq!(CURATED_TRIGGERS.len(), 23);
    }

    #[test]
    fn glob_star_at_both_ends() {
        assert!(glob_match("*Mu*", "HLT_IsoMu24"));
        assert!(glob_match("*Mu*", "Mu"));
        assert!(glob_match("*_pt*", "Jet_pt"));
        assert!(glob_match("*_pt*", "Jet_pt_raw"));
        assert!(!glob_match("*Mu*", "HLT_Ele32"));
        // Leading/trailing stars may match empty runs.
        assert!(glob_match("*Jet*", "Jet"));
        assert!(glob_match("**x**", "x"));
    }

    #[test]
    fn glob_question_mark_counts_chars_not_bytes() {
        // `?` matches exactly one *character*, including multibyte ones.
        assert!(glob_match("?", "é"));
        assert!(glob_match("J?t_pt", "Jét_pt"));
        assert!(glob_match("??", "ηφ"));
        assert!(!glob_match("?", "ab"));
        assert!(!glob_match("??", "é"));
        // Mixed with literals and stars.
        assert!(glob_match("*_?t", "Jet_pt"));
        assert!(!glob_match("J?t", "Jt"));
    }

    #[test]
    fn glob_empty_pattern_and_name_edges() {
        assert!(glob_match("", ""));
        assert!(!glob_match("", "x"));
        assert!(glob_match("*", ""));
        assert!(glob_match("***", ""));
        assert!(!glob_match("?", ""));
        assert!(!glob_match("a*", ""));
    }

    #[test]
    fn expand_with_empty_pattern_warns_and_selects_nothing() {
        let e = expand(&[String::new()], &schema(), false);
        assert!(e.selected.is_empty());
        assert_eq!(e.warnings.len(), 1);
    }

    #[test]
    fn curated_mapping_only_hits_broad_hlt_wildcards() {
        // An exact HLT name (no wildcard) bypasses the curated mapping
        // even when the branch is not in the curated set.
        let e = expand(&["HLT_Obscure_Path_v3".to_string()], &schema(), false);
        assert_eq!(e.selected, vec!["HLT_Obscure_Path_v3"]);
        assert!(e.warnings.is_empty());
        // A narrower HLT wildcard is still "broad" (contains `*`).
        let e2 = expand(&["HLT_*Rare*".to_string()], &schema(), false);
        assert!(e2.selected.is_empty());
        assert!(!e2.warnings.is_empty());
    }

    #[test]
    fn force_all_vs_curated_on_same_schema() {
        let curated = expand(&["HLT_*".to_string()], &schema(), false);
        let forced = expand(&["HLT_*".to_string()], &schema(), true);
        // force_all keeps a strict superset of the curated expansion.
        assert!(curated.selected.iter().all(|b| forced.selected.contains(b)));
        assert!(forced.selected.len() > curated.selected.len());
        assert!(forced.warnings.is_empty());
        assert!(curated.warnings[0].contains("force_all"));
    }
}
