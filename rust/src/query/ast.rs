//! Query schema: the structured JSON selection format of Figure 2c,
//! plus the open `cut` expression frontend.
//!
//! A query names the input dataset, the output file, the branches to
//! keep (with wildcards), and a **multi-stage selection**:
//!
//! 1. *preselection* — cheap single-branch scalar cuts ("at least one
//!    high-quality lepton"), evaluated first to discard events early;
//! 2. *object-level* — per-particle kinematic/ID cuts over jagged
//!    collections (electrons, muons, jets) with a minimum surviving
//!    multiplicity;
//! 3. *event-level* — composite variables: HT (scalar sum of jet pT
//!    above a threshold) and a trigger OR.
//!
//! Since the IR redesign the structured selection is **sugar over the
//! open expression IR** ([`crate::query::expr::Expr`]):
//! [`Selection::to_expr`] lowers the three stages onto ordinary
//! expressions (HT becomes `sum(Jet_pt[Jet_pt > 30]) >= 200`, the
//! trigger OR becomes plain `||`), and branch derivation
//! ([`Selection::referenced_branches`]) walks the lowered IR. The
//! legacy JSON payload parses byte-for-byte unchanged. Queries may
//! additionally (or instead) carry a free-form `"cut"` string — the
//! TCut-style frontend of [`crate::query::parse`] — which is ANDed
//! with the structured stages.
//!
//! Example payload:
//!
//! ```json
//! {
//!   "input": "store/higgs.troot",
//!   "output": "skim.troot",
//!   "branches": ["Electron_*", "Muon_*", "Jet_pt", "MET_pt", "HLT_*"],
//!   "force_all": false,
//!   "selection": {
//!     "preselection": [ {"branch": "nElectron", "op": ">=", "value": 1} ],
//!     "objects": [
//!       { "collection": "Electron", "min_count": 1, "cuts": [
//!           {"var": "Electron_pt",  "op": ">",   "value": 25.0},
//!           {"var": "Electron_eta", "op": "|<|", "value": 2.4} ] }
//!     ],
//!     "event": {
//!       "ht": {"jet_pt": "Jet_pt", "object_pt_min": 30.0, "min": 200.0},
//!       "triggers_any": ["HLT_IsoMu24", "HLT_Ele27_WPTight"]
//!     }
//!   },
//!   "cut": "MET_pt > 100 || sum(Jet_pt[Jet_pt > 30]) > 250"
//! }
//! ```

use super::dataset::DatasetSpec;
use super::expr::Expr;
use super::json::Json;
use super::parse;
use crate::{Error, Result};
use std::collections::BTreeMap;

/// Comparison operator. `AbsLt`/`AbsGt` compare `|x|` (the idiomatic
/// `|eta| < 2.4` acceptance cut).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    /// `>`.
    Gt,
    /// `>=`.
    Ge,
    /// `<`.
    Lt,
    /// `<=`.
    Le,
    /// `==`.
    Eq,
    /// `!=`.
    Ne,
    /// `|x| <` (absolute-value less-than).
    AbsLt,
    /// `|x| >` (absolute-value greater-than).
    AbsGt,
}

impl CmpOp {
    /// Parse the JSON-payload operator spelling (`">="`, `"|<|"`...).
    pub fn parse(s: &str) -> Result<CmpOp> {
        Ok(match s {
            ">" => CmpOp::Gt,
            ">=" => CmpOp::Ge,
            "<" => CmpOp::Lt,
            "<=" => CmpOp::Le,
            "==" => CmpOp::Eq,
            "!=" => CmpOp::Ne,
            "|<|" => CmpOp::AbsLt,
            "|>|" => CmpOp::AbsGt,
            other => return Err(Error::query(format!("unknown operator '{other}'"))),
        })
    }

    /// The canonical spelling (inverse of [`CmpOp::parse`]).
    pub fn symbol(self) -> &'static str {
        match self {
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Eq => "==",
            CmpOp::Ne => "!=",
            CmpOp::AbsLt => "|<|",
            CmpOp::AbsGt => "|>|",
        }
    }

    /// Apply the comparison.
    #[inline]
    pub fn eval(self, x: f64, v: f64) -> bool {
        match self {
            CmpOp::Gt => x > v,
            CmpOp::Ge => x >= v,
            CmpOp::Lt => x < v,
            CmpOp::Le => x <= v,
            CmpOp::Eq => x == v,
            CmpOp::Ne => x != v,
            CmpOp::AbsLt => x.abs() < v,
            CmpOp::AbsGt => x.abs() > v,
        }
    }

    /// Numeric opcode for the AOT kernel's cut bank (must match
    /// `python/compile/kernels/skim.py`).
    pub fn code(self) -> (u8, bool) {
        match self {
            CmpOp::Gt => (0, false),
            CmpOp::Ge => (1, false),
            CmpOp::Lt => (2, false),
            CmpOp::Le => (3, false),
            CmpOp::Eq => (4, false),
            CmpOp::Ne => (5, false),
            CmpOp::AbsLt => (2, true),
            CmpOp::AbsGt => (0, true),
        }
    }

    /// Lower `lhs OP value` onto the IR (`AbsLt`/`AbsGt` wrap the lhs
    /// in `abs(..)`).
    pub fn lower(self, lhs: Expr, value: f64) -> Expr {
        match self {
            CmpOp::Gt => lhs.gt(value),
            CmpOp::Ge => lhs.ge(value),
            CmpOp::Lt => lhs.lt(value),
            CmpOp::Le => lhs.le(value),
            CmpOp::Eq => lhs.eq(value),
            CmpOp::Ne => lhs.ne(value),
            CmpOp::AbsLt => lhs.abs().lt(value),
            CmpOp::AbsGt => lhs.abs().gt(value),
        }
    }
}

/// Scalar-branch cut (preselection stage).
#[derive(Debug, Clone, PartialEq)]
pub struct ScalarCut {
    /// Scalar branch to test.
    pub branch: String,
    /// Comparison operator.
    pub op: CmpOp,
    /// Threshold.
    pub value: f64,
}

/// Per-object cut over one jagged variable.
#[derive(Debug, Clone, PartialEq)]
pub struct ObjectCut {
    /// Jagged branch to test (e.g. `Electron_pt`).
    pub var: String,
    /// Comparison operator.
    pub op: CmpOp,
    /// Threshold.
    pub value: f64,
}

/// Object-level selection: an event passes if at least `min_count`
/// objects of `collection` satisfy **all** `cuts`.
#[derive(Debug, Clone, PartialEq)]
pub struct ObjectSelection {
    /// Collection prefix (`Electron`, `Jet`, ...).
    pub collection: String,
    /// Per-object cuts, all of which must hold.
    pub cuts: Vec<ObjectCut>,
    /// Minimum number of surviving objects.
    pub min_count: u32,
}

/// HT cut: scalar sum of `jet_pt` over objects with pT above
/// `object_pt_min` must be at least `min`.
#[derive(Debug, Clone, PartialEq)]
pub struct HtCut {
    /// The jet-pT branch summed (usually `Jet_pt`).
    pub jet_pt: String,
    /// Per-object pT threshold for inclusion in the sum.
    pub object_pt_min: f64,
    /// Minimum HT for the event to pass.
    pub min: f64,
}

/// Event-level selection: composite variables + trigger OR.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct EventSelection {
    /// Optional HT requirement.
    pub ht: Option<HtCut>,
    /// Event passes if **any** listed trigger flag is set. Empty = no
    /// trigger requirement.
    pub triggers_any: Vec<String>,
}

/// The full multi-stage selection.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Selection {
    /// Cheap scalar cuts, evaluated first.
    pub preselection: Vec<ScalarCut>,
    /// Per-collection object groups.
    pub objects: Vec<ObjectSelection>,
    /// Composite event-level stage (HT, trigger OR).
    pub event: EventSelection,
}

impl Selection {
    /// Lower the structured stages onto the open IR: preselection cuts
    /// become scalar comparisons, each object group becomes
    /// `count(cut && ..) >= min_count`, HT becomes
    /// `sum(jet[jet > ptmin]) >= min`, and the trigger list becomes a
    /// plain `||` chain — all ANDed left-to-right in stage order.
    /// `None` for the empty selection (copy-all).
    pub fn to_expr(&self) -> Option<Expr> {
        let mut terms: Vec<Expr> = Vec::new();
        for c in &self.preselection {
            terms.push(c.op.lower(Expr::branch(&c.branch), c.value));
        }
        for sel in &self.objects {
            let mut pred: Option<Expr> = None;
            for c in &sel.cuts {
                let t = c.op.lower(Expr::branch(&c.var), c.value);
                pred = Some(match pred {
                    Some(p) => p.and(t),
                    None => t,
                });
            }
            if let Some(pred) = pred {
                terms.push(Expr::count(pred).ge(sel.min_count as f64));
            }
        }
        if let Some(ht) = &self.event.ht {
            let jet = Expr::branch(&ht.jet_pt);
            terms.push(
                Expr::sum_if(jet, Expr::branch(&ht.jet_pt).gt(ht.object_pt_min)).ge(ht.min),
            );
        }
        if !self.event.triggers_any.is_empty() {
            let mut trig: Option<Expr> = None;
            for t in &self.event.triggers_any {
                let b = Expr::branch(t);
                trig = Some(match trig {
                    Some(x) => x.or(b),
                    None => b,
                });
            }
            terms.extend(trig);
        }
        terms.into_iter().reduce(|a, b| a.and(b))
    }

    /// All branches the selection reads (the *filtering criteria*
    /// branches of §3.1) — derived by walking the lowered IR.
    pub fn referenced_branches(&self) -> Vec<String> {
        match self.to_expr() {
            Some(e) => e.branches(),
            None => Vec::new(),
        }
    }

    /// True when no stage carries any cut (copy-all).
    pub fn is_empty(&self) -> bool {
        self.preselection.is_empty()
            && self.objects.is_empty()
            && self.event.ht.is_none()
            && self.event.triggers_any.is_empty()
    }
}

/// A complete skim request.
#[derive(Debug, Clone, PartialEq)]
pub struct SkimQuery {
    /// The input dataset: one catalog-relative file (the legacy
    /// single-file job), an explicit file list, a glob over the
    /// storage export, or a named catalog. See
    /// [`crate::query::DatasetSpec`] and [`crate::catalog`].
    pub input: DatasetSpec,
    /// Output file name for the filtered result.
    pub output: String,
    /// Branch patterns to keep in the output (wildcards allowed).
    pub branches: Vec<String>,
    /// Disable the curated wildcard mapping (§3.1): expand patterns
    /// against the *full* schema.
    pub force_all: bool,
    /// The structured Figure-2c multi-stage selection (sugar over the
    /// IR since the redesign).
    pub selection: Selection,
    /// Free-form IR cut, ANDed with the structured selection. Carried
    /// in the JSON payload as a TCut-style `"cut"` string.
    pub cut: Option<Expr>,
}

impl SkimQuery {
    /// A fresh query: keep every branch, select every event. Chain the
    /// fluent builders to shape it. The input accepts any dataset-spec
    /// spelling — a single file, a glob over the storage export, or a
    /// `catalog:NAME` reference:
    ///
    /// ```
    /// use skimroot::query::{DatasetSpec, Expr, SkimQuery};
    ///
    /// let q = SkimQuery::new("events.troot", "skim.troot")
    ///     .keep(&["Muon_*", "MET_pt", "HLT_Mu50"])
    ///     .with_cut(Expr::branch("nMuon").ge(2))
    ///     .with_cut_str("HLT_Mu50 || max(Muon_pt) > 100")
    ///     .unwrap();
    /// assert_eq!(q.referenced_branches(), vec!["nMuon", "HLT_Mu50", "Muon_pt"]);
    ///
    /// let d = SkimQuery::new("store/*.troot", "skim.troot");
    /// assert_eq!(d.input, DatasetSpec::Glob("store/*.troot".into()));
    /// ```
    pub fn new(input: impl Into<DatasetSpec>, output: impl Into<String>) -> SkimQuery {
        SkimQuery {
            input: input.into(),
            output: output.into(),
            branches: vec!["*".to_string()],
            force_all: false,
            selection: Selection::default(),
            cut: None,
        }
    }

    /// The per-file sub-query the dataset layer executes: same
    /// selection and branch patterns, input pinned to one resolved
    /// file, output renamed to the per-file part name.
    pub fn for_file(&self, path: &str, part_output: impl Into<String>) -> SkimQuery {
        let mut q = self.clone();
        q.input = DatasetSpec::File(path.to_string());
        q.output = part_output.into();
        q
    }

    /// Output branch patterns to keep (wildcards allowed).
    pub fn keep(mut self, patterns: &[&str]) -> Self {
        self.branches = patterns.iter().map(|p| p.to_string()).collect();
        self
    }

    /// Disable the curated wildcard mapping (§3.1).
    pub fn force_all(mut self, force: bool) -> Self {
        self.force_all = force;
        self
    }

    /// AND an IR expression onto the query's cut (composes with the
    /// structured selection and any earlier cut).
    pub fn with_cut(mut self, expr: impl Into<Expr>) -> Self {
        let expr = expr.into();
        self.cut = Some(match self.cut.take() {
            Some(prev) => prev.and(expr),
            None => expr,
        });
        self
    }

    /// AND a TCut-style cut string onto the query.
    ///
    /// ```
    /// use skimroot::SkimQuery;
    ///
    /// let q = SkimQuery::new("in.troot", "out.troot")
    ///     .with_cut_str("MET_pt > 100 || sum(Jet_pt[Jet_pt > 30]) > 250")
    ///     .unwrap();
    /// assert_eq!(
    ///     q.combined_cut().unwrap().to_string(),
    ///     "((MET_pt > 100) || (sum(Jet_pt[(Jet_pt > 30)]) > 250))"
    /// );
    /// ```
    pub fn with_cut_str(self, text: &str) -> Result<Self> {
        Ok(self.with_cut(parse::parse_cut(text)?))
    }

    /// The complete selection as one IR expression: the lowered
    /// structured stages ANDed with the free-form cut. `None` =
    /// copy-all.
    pub fn combined_cut(&self) -> Option<Expr> {
        match (self.selection.to_expr(), self.cut.clone()) {
            (Some(a), Some(b)) => Some(a.and(b)),
            (Some(a), None) => Some(a),
            (None, b) => b,
        }
    }

    /// Every branch the query's selection reads (structured stages
    /// first, then cut-only branches), deduplicated in first-use order.
    pub fn referenced_branches(&self) -> Vec<String> {
        let mut out = self.selection.referenced_branches();
        if let Some(cut) = &self.cut {
            for b in cut.branches() {
                if !out.contains(&b) {
                    out.push(b);
                }
            }
        }
        out
    }

    /// Parse and validate a JSON query payload.
    pub fn from_json_text(text: &str) -> Result<SkimQuery> {
        Self::from_json(&Json::parse(text)?)
    }

    /// Validate an already-parsed JSON payload (errors carry field
    /// paths, e.g. `selection.objects[0].cuts[1].op`).
    pub fn from_json(v: &Json) -> Result<SkimQuery> {
        // `input` is a string for single-file / glob / catalog specs
        // (legacy payloads unchanged) or an array of strings for an
        // explicit dataset file list.
        let input = match v.get("input") {
            Some(Json::Str(s)) => {
                if s.is_empty() {
                    return Err(Error::query("input: must not be empty"));
                }
                DatasetSpec::parse(s)
            }
            Some(Json::Arr(items)) => {
                if items.is_empty() {
                    return Err(Error::query("input: file list must not be empty"));
                }
                let files = items
                    .iter()
                    .enumerate()
                    .map(|(i, f)| {
                        f.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| Error::query(format!("input[{i}]: must be a string")))
                    })
                    .collect::<Result<Vec<_>>>()?;
                DatasetSpec::Files(files)
            }
            Some(_) => {
                return Err(Error::query("input: must be a string or an array of strings"))
            }
            None => return Err(Error::query("input: missing required field")),
        };
        let output = str_at(v, "", "output")?;
        if output.is_empty() {
            return Err(Error::query("output: must not be empty"));
        }
        let branches = match v.get("branches") {
            Some(Json::Arr(items)) => items
                .iter()
                .enumerate()
                .map(|(i, b)| {
                    b.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| Error::query(format!("branches[{i}]: must be a string")))
                })
                .collect::<Result<Vec<_>>>()?,
            Some(_) => return Err(Error::query("branches: must be an array")),
            None => vec!["*".to_string()],
        };
        let force_all = match v.get("force_all") {
            Some(Json::Bool(b)) => *b,
            Some(_) => return Err(Error::query("force_all: must be a boolean")),
            None => false,
        };
        let selection = match v.get("selection") {
            Some(sel) => parse_selection(sel)?,
            None => Selection::default(),
        };
        let cut = match v.get("cut") {
            Some(Json::Str(s)) => match parse::parse_cut(s) {
                Ok(e) => Some(e),
                Err(Error::Query(msg)) => return Err(Error::query(format!("cut: {msg}"))),
                Err(e) => return Err(e),
            },
            Some(_) => return Err(Error::query("cut: must be a string")),
            None => None,
        };
        Ok(SkimQuery { input, output, branches, force_all, selection, cut })
    }

    /// Serialize back to the canonical JSON payload (used to POST the
    /// query to the DPU and to hash job ids). The `cut` field renders
    /// as its canonical cut-string (absent when no cut is set, so
    /// legacy payloads round-trip byte-for-byte).
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        let input_json = match &self.input {
            DatasetSpec::Files(files) => {
                Json::Arr(files.iter().map(|f| Json::Str(f.clone())).collect())
            }
            spec => Json::Str(spec.to_string()),
        };
        obj.insert("input".into(), input_json);
        obj.insert("output".into(), Json::Str(self.output.clone()));
        obj.insert(
            "branches".into(),
            Json::Arr(self.branches.iter().map(|b| Json::Str(b.clone())).collect()),
        );
        obj.insert("force_all".into(), Json::Bool(self.force_all));
        if let Some(cut) = &self.cut {
            obj.insert("cut".into(), Json::Str(cut.to_string()));
        }
        let mut sel = BTreeMap::new();
        sel.insert(
            "preselection".into(),
            Json::Arr(
                self.selection
                    .preselection
                    .iter()
                    .map(|c| {
                        let mut m = BTreeMap::new();
                        m.insert("branch".into(), Json::Str(c.branch.clone()));
                        m.insert("op".into(), Json::Str(c.op.symbol().into()));
                        m.insert("value".into(), Json::Num(c.value));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        sel.insert(
            "objects".into(),
            Json::Arr(
                self.selection
                    .objects
                    .iter()
                    .map(|s| {
                        let mut m = BTreeMap::new();
                        m.insert("collection".into(), Json::Str(s.collection.clone()));
                        m.insert("min_count".into(), Json::Num(s.min_count as f64));
                        m.insert(
                            "cuts".into(),
                            Json::Arr(
                                s.cuts
                                    .iter()
                                    .map(|c| {
                                        let mut m = BTreeMap::new();
                                        m.insert("var".into(), Json::Str(c.var.clone()));
                                        m.insert("op".into(), Json::Str(c.op.symbol().into()));
                                        m.insert("value".into(), Json::Num(c.value));
                                        Json::Obj(m)
                                    })
                                    .collect(),
                            ),
                        );
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        let mut ev = BTreeMap::new();
        if let Some(ht) = &self.selection.event.ht {
            let mut m = BTreeMap::new();
            m.insert("jet_pt".into(), Json::Str(ht.jet_pt.clone()));
            m.insert("object_pt_min".into(), Json::Num(ht.object_pt_min));
            m.insert("min".into(), Json::Num(ht.min));
            ev.insert("ht".into(), Json::Obj(m));
        }
        if !self.selection.event.triggers_any.is_empty() {
            ev.insert(
                "triggers_any".into(),
                Json::Arr(
                    self.selection
                        .event
                        .triggers_any
                        .iter()
                        .map(|t| Json::Str(t.clone()))
                        .collect(),
                ),
            );
        }
        sel.insert("event".into(), Json::Obj(ev));
        obj.insert("selection".into(), Json::Obj(sel));
        Json::Obj(obj)
    }
}

// ---- path-aware JSON field access -----------------------------------
//
// Validation errors carry the JSON path to the offending field
// (`selection.objects[0].cuts[1].op: unknown operator '=>'`) instead
// of a bare message.

fn at(path: &str, key: &str) -> String {
    if path.is_empty() {
        key.to_string()
    } else {
        format!("{path}.{key}")
    }
}

fn str_at(v: &Json, path: &str, key: &str) -> Result<String> {
    match v.get(key) {
        Some(Json::Str(s)) => Ok(s.clone()),
        Some(_) => Err(Error::query(format!("{}: must be a string", at(path, key)))),
        None => Err(Error::query(format!("{}: missing required field", at(path, key)))),
    }
}

fn num_at(v: &Json, path: &str, key: &str) -> Result<f64> {
    match v.get(key) {
        Some(Json::Num(n)) => Ok(*n),
        Some(_) => Err(Error::query(format!("{}: must be a number", at(path, key)))),
        None => Err(Error::query(format!("{}: missing required field", at(path, key)))),
    }
}

fn arr_at<'a>(v: &'a Json, path: &str) -> Result<&'a [Json]> {
    v.as_arr()
        .ok_or_else(|| Error::query(format!("{path}: must be an array")))
}

fn op_at(item: &Json, path: &str) -> Result<CmpOp> {
    let s = str_at(item, path, "op")?;
    CmpOp::parse(&s)
        .map_err(|_| Error::query(format!("{}: unknown operator '{s}'", at(path, "op"))))
}

fn parse_selection(v: &Json) -> Result<Selection> {
    let mut sel = Selection::default();
    if let Some(pre) = v.get("preselection") {
        let items = arr_at(pre, "selection.preselection")?;
        for (i, item) in items.iter().enumerate() {
            let path = format!("selection.preselection[{i}]");
            sel.preselection.push(ScalarCut {
                branch: str_at(item, &path, "branch")?,
                op: op_at(item, &path)?,
                value: num_at(item, &path, "value")?,
            });
        }
    }
    if let Some(objs) = v.get("objects") {
        let items = arr_at(objs, "selection.objects")?;
        for (i, item) in items.iter().enumerate() {
            let path = format!("selection.objects[{i}]");
            let collection = str_at(item, &path, "collection")?;
            let min_count = match item.get("min_count") {
                Some(n) => {
                    let f = n.as_f64().ok_or_else(|| {
                        Error::query(format!("{}: must be a number", at(&path, "min_count")))
                    })?;
                    if f < 0.0 || f.fract() != 0.0 {
                        return Err(Error::query(format!(
                            "{}: must be a non-negative integer",
                            at(&path, "min_count")
                        )));
                    }
                    f as u32
                }
                None => 1,
            };
            let cuts_path = at(&path, "cuts");
            let cuts_json = match item.get("cuts") {
                Some(c) => arr_at(c, &cuts_path)?,
                None => {
                    return Err(Error::query(format!("{cuts_path}: missing required field")));
                }
            };
            if cuts_json.is_empty() {
                return Err(Error::query(format!(
                    "{cuts_path}: object selection for '{collection}' has no cuts"
                )));
            }
            let mut cuts = Vec::new();
            for (j, c) in cuts_json.iter().enumerate() {
                let cpath = format!("{path}.cuts[{j}]");
                let var = str_at(c, &cpath, "var")?;
                if !var.starts_with(&format!("{collection}_")) {
                    return Err(Error::query(format!(
                        "{}: cut variable '{var}' does not belong to collection '{collection}'",
                        at(&cpath, "var")
                    )));
                }
                cuts.push(ObjectCut {
                    var,
                    op: op_at(c, &cpath)?,
                    value: num_at(c, &cpath, "value")?,
                });
            }
            sel.objects.push(ObjectSelection { collection, cuts, min_count });
        }
    }
    if let Some(ev) = v.get("event") {
        if let Some(ht) = ev.get("ht") {
            let hpath = "selection.event.ht";
            sel.event.ht = Some(HtCut {
                jet_pt: str_at(ht, hpath, "jet_pt")?,
                object_pt_min: ht.get("object_pt_min").and_then(|v| v.as_f64()).unwrap_or(0.0),
                min: num_at(ht, hpath, "min")?,
            });
        }
        if let Some(trig) = ev.get("triggers_any") {
            let items = arr_at(trig, "selection.event.triggers_any")?;
            for (i, t) in items.iter().enumerate() {
                sel.event.triggers_any.push(
                    t.as_str()
                        .ok_or_else(|| {
                            Error::query(format!(
                                "selection.event.triggers_any[{i}]: must be a string"
                            ))
                        })?
                        .to_string(),
                );
            }
        }
    }
    Ok(sel)
}

#[cfg(test)]
mod tests {
    use super::*;

    pub const SAMPLE: &str = r#"{
        "input": "store/higgs.troot",
        "output": "skim.troot",
        "branches": ["Electron_*", "Muon_*", "Jet_pt", "MET_pt", "HLT_*"],
        "force_all": false,
        "selection": {
            "preselection": [ {"branch": "nElectron", "op": ">=", "value": 1} ],
            "objects": [
                { "collection": "Electron", "min_count": 1, "cuts": [
                    {"var": "Electron_pt",  "op": ">",   "value": 25.0},
                    {"var": "Electron_eta", "op": "|<|", "value": 2.4} ] }
            ],
            "event": {
                "ht": {"jet_pt": "Jet_pt", "object_pt_min": 30.0, "min": 200.0},
                "triggers_any": ["HLT_IsoMu24", "HLT_Ele27_WPTight"]
            }
        }
    }"#;

    #[test]
    fn parses_full_query() {
        let q = SkimQuery::from_json_text(SAMPLE).unwrap();
        assert_eq!(q.input, "store/higgs.troot");
        assert_eq!(q.branches.len(), 5);
        assert!(!q.force_all);
        assert_eq!(q.selection.preselection.len(), 1);
        assert_eq!(q.selection.objects[0].cuts.len(), 2);
        assert_eq!(q.selection.objects[0].min_count, 1);
        let ht = q.selection.event.ht.as_ref().unwrap();
        assert_eq!(ht.min, 200.0);
        assert_eq!(q.selection.event.triggers_any.len(), 2);
        assert!(q.cut.is_none());
    }

    #[test]
    fn json_roundtrip() {
        let q = SkimQuery::from_json_text(SAMPLE).unwrap();
        let text = q.to_json().to_string();
        let q2 = SkimQuery::from_json_text(&text).unwrap();
        assert_eq!(q, q2);
    }

    #[test]
    fn legacy_payload_serialization_is_stable() {
        // A legacy (no-cut) query must serialize without any new
        // fields: reserializing the parse of its own serialization is
        // byte-identical, and no "cut" key appears.
        let q = SkimQuery::from_json_text(SAMPLE).unwrap();
        let text = q.to_json().to_string();
        assert!(!text.contains("\"cut\""));
        let q2 = SkimQuery::from_json_text(&text).unwrap();
        assert_eq!(q2.to_json().to_string(), text);
    }

    #[test]
    fn cut_field_parses_and_roundtrips() {
        let q = SkimQuery::from_json_text(
            r#"{"input": "a.troot", "output": "b.troot",
                "cut": "nMuon >= 2 && (HLT_Mu50 || max(Muon_pt) > 100)"}"#,
        )
        .unwrap();
        assert!(q.cut.is_some());
        assert_eq!(q.referenced_branches(), vec!["nMuon", "HLT_Mu50", "Muon_pt"]);
        let text = q.to_json().to_string();
        let q2 = SkimQuery::from_json_text(&text).unwrap();
        assert_eq!(q, q2);
    }

    #[test]
    fn selection_lowers_to_ir() {
        let q = SkimQuery::from_json_text(SAMPLE).unwrap();
        let e = q.selection.to_expr().unwrap();
        assert_eq!(
            e.to_string(),
            "((((nElectron >= 1) && \
               (count(((Electron_pt > 25) && (abs(Electron_eta) < 2.4))) >= 1)) && \
               (sum(Jet_pt[(Jet_pt > 30)]) >= 200)) && \
               (HLT_IsoMu24 || HLT_Ele27_WPTight))"
        );
        // The lowered form reparses to the identical AST.
        assert_eq!(super::parse::parse_cut(&e.to_string()).unwrap(), e);
    }

    #[test]
    fn referenced_branches_cover_all_stages() {
        let q = SkimQuery::from_json_text(SAMPLE).unwrap();
        let refs = q.selection.referenced_branches();
        for b in ["nElectron", "Electron_pt", "Electron_eta", "Jet_pt", "HLT_IsoMu24"] {
            assert!(refs.iter().any(|r| r == b), "missing {b}");
        }
        // deduplicated
        let mut sorted = refs.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), refs.len());
    }

    #[test]
    fn query_referenced_branches_merge_cut() {
        let q = SkimQuery::from_json_text(SAMPLE)
            .unwrap()
            .with_cut_str("MET_pt > 100 && nElectron >= 1")
            .unwrap();
        let refs = q.referenced_branches();
        // Cut-only branches appended; duplicates with the structured
        // stages are not repeated.
        assert_eq!(refs.iter().filter(|b| *b == "nElectron").count(), 1);
        assert!(refs.iter().any(|b| b == "MET_pt"));
        assert_eq!(refs.last().unwrap(), "MET_pt");
    }

    #[test]
    fn dataset_input_forms_roundtrip() {
        // Glob spelling stays a string field.
        let q = SkimQuery::from_json_text(
            r#"{"input": "store/*.troot", "output": "b.troot"}"#,
        )
        .unwrap();
        assert_eq!(q.input, DatasetSpec::Glob("store/*.troot".into()));
        let q2 = SkimQuery::from_json_text(&q.to_json().to_string()).unwrap();
        assert_eq!(q, q2);
        // Named catalog.
        let q = SkimQuery::from_json_text(
            r#"{"input": "catalog:run2018", "output": "b.troot"}"#,
        )
        .unwrap();
        assert_eq!(q.input, DatasetSpec::Catalog("run2018".into()));
        assert_eq!(SkimQuery::from_json_text(&q.to_json().to_string()).unwrap(), q);
        // Explicit file list serializes as an array.
        let q = SkimQuery::from_json_text(
            r#"{"input": ["a.troot", "b.troot"], "output": "b.troot"}"#,
        )
        .unwrap();
        assert_eq!(q.input, DatasetSpec::Files(vec!["a.troot".into(), "b.troot".into()]));
        let text = q.to_json().to_string();
        assert!(text.contains(r#""input":["a.troot","b.troot"]"#), "{text}");
        assert_eq!(SkimQuery::from_json_text(&text).unwrap(), q);
        // Invalid list payloads.
        for bad in [
            r#"{"input": [], "output": "b"}"#,
            r#"{"input": ["a", 3], "output": "b"}"#,
            r#"{"input": 7, "output": "b"}"#,
        ] {
            assert!(SkimQuery::from_json_text(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn defaults_apply() {
        let q = SkimQuery::from_json_text(
            r#"{"input": "a.troot", "output": "b.troot"}"#,
        )
        .unwrap();
        assert_eq!(q.branches, vec!["*"]);
        assert!(!q.force_all);
        assert!(q.selection.is_empty());
        assert!(q.combined_cut().is_none());
    }

    #[test]
    fn fluent_builder_composes_cuts() {
        let q = SkimQuery::new("in.troot", "out.troot")
            .keep(&["Muon_*", "MET_pt"])
            .force_all(true)
            .with_cut(Expr::branch("nMuon").ge(2))
            .with_cut_str("MET_pt > 50")
            .unwrap();
        assert_eq!(q.branches, vec!["Muon_*", "MET_pt"]);
        assert!(q.force_all);
        assert_eq!(
            q.cut.as_ref().unwrap().to_string(),
            "((nMuon >= 2) && (MET_pt > 50))"
        );
        assert_eq!(q.combined_cut(), q.cut);
    }

    #[test]
    fn rejects_invalid_queries() {
        for bad in [
            r#"{"output": "b"}"#,                                   // no input
            r#"{"input": "", "output": "b"}"#,                      // empty input
            r#"{"input": "a", "output": "b", "branches": "x"}"#,    // branches not array
            r#"{"input": "a", "output": "b", "force_all": 1}"#,     // force_all not bool
            r#"{"input": "a", "output": "b", "cut": 7}"#,           // cut not a string
            r#"{"input": "a", "output": "b", "cut": "x &&"}"#,      // malformed cut
            r#"{"input": "a", "output": "b", "selection": {"preselection": [{"branch": "x", "op": "~", "value": 1}]}}"#,
            r#"{"input": "a", "output": "b", "selection": {"objects": [{"collection": "El", "cuts": []}]}}"#,
            r#"{"input": "a", "output": "b", "selection": {"objects": [{"collection": "El", "cuts": [{"var": "Mu_pt", "op": ">", "value": 1}]}]}}"#,
            r#"{"input": "a", "output": "b", "selection": {"objects": [{"collection": "El", "min_count": -1, "cuts": [{"var": "El_pt", "op": ">", "value": 1}]}]}}"#,
        ] {
            assert!(SkimQuery::from_json_text(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn validation_errors_carry_json_paths() {
        let cases = [
            (
                r#"{"input": "a", "output": "b", "selection": {"objects": [
                    {"collection": "El", "cuts": [
                        {"var": "El_pt", "op": ">", "value": 1},
                        {"var": "El_eta", "op": "=>", "value": 2.4}]}]}}"#,
                "selection.objects[0].cuts[1].op: unknown operator '=>'",
            ),
            (
                r#"{"input": "a", "output": "b", "selection": {"preselection": [
                    {"branch": "x", "op": ">"}]}}"#,
                "selection.preselection[0].value: missing required field",
            ),
            (
                r#"{"input": "a", "output": "b", "selection": {"objects": [
                    {"collection": "El", "min_count": 1.5, "cuts": [
                        {"var": "El_pt", "op": ">", "value": 1}]}]}}"#,
                "selection.objects[0].min_count: must be a non-negative integer",
            ),
            (
                r#"{"input": "a", "output": "b", "selection": {"event":
                    {"triggers_any": ["HLT_X", 3]}}}"#,
                "selection.event.triggers_any[1]: must be a string",
            ),
            (
                r#"{"input": "a", "output": "b", "branches": ["ok", 1]}"#,
                "branches[1]: must be a string",
            ),
            (
                r#"{"input": "a", "output": "b", "cut": "a >< b"}"#,
                "cut: cut parse error at char",
            ),
        ];
        for (bad, needle) in cases {
            let err = SkimQuery::from_json_text(bad).unwrap_err();
            let msg = format!("{err}");
            assert!(msg.contains(needle), "expected '{needle}' in: {msg}");
        }
    }

    #[test]
    fn cmp_op_eval_semantics() {
        assert!(CmpOp::Gt.eval(2.0, 1.0));
        assert!(!CmpOp::Gt.eval(1.0, 1.0));
        assert!(CmpOp::Ge.eval(1.0, 1.0));
        assert!(CmpOp::AbsLt.eval(-2.0, 2.4));
        assert!(!CmpOp::AbsLt.eval(-3.0, 2.4));
        assert!(CmpOp::AbsGt.eval(-3.0, 2.4));
        assert!(CmpOp::Ne.eval(1.0, 2.0));
        for op in [CmpOp::Gt, CmpOp::Ge, CmpOp::Lt, CmpOp::Le, CmpOp::Eq, CmpOp::Ne, CmpOp::AbsLt, CmpOp::AbsGt] {
            assert_eq!(CmpOp::parse(op.symbol()).unwrap(), op);
        }
    }

    #[test]
    fn prop_query_json_roundtrip() {
        use crate::util::Pcg32;
        fn gen_selection(rng: &mut Pcg32) -> Selection {
            let ops = [CmpOp::Gt, CmpOp::Ge, CmpOp::Lt, CmpOp::Le, CmpOp::Eq, CmpOp::Ne, CmpOp::AbsLt, CmpOp::AbsGt];
            let op = |rng: &mut Pcg32| ops[rng.below(ops.len() as u32) as usize];
            let val = |rng: &mut Pcg32| (rng.below(4000) as f64 - 2000.0) / 16.0;
            let mut sel = Selection::default();
            for i in 0..rng.below(3) {
                sel.preselection.push(ScalarCut {
                    branch: format!("scal{i}"),
                    op: op(rng),
                    value: val(rng),
                });
            }
            for i in 0..rng.below(3) {
                let coll = format!("C{i}");
                let cuts = (0..1 + rng.below(3))
                    .map(|j| ObjectCut {
                        var: format!("{coll}_v{j}"),
                        op: op(rng),
                        value: val(rng),
                    })
                    .collect();
                sel.objects.push(ObjectSelection {
                    collection: coll,
                    cuts,
                    min_count: rng.below(4),
                });
            }
            if rng.chance(0.5) {
                sel.event.ht = Some(HtCut {
                    jet_pt: "Jet_pt".into(),
                    object_pt_min: val(rng).abs(),
                    min: val(rng).abs(),
                });
            }
            for i in 0..rng.below(3) {
                sel.event.triggers_any.push(format!("HLT_T{i}"));
            }
            sel
        }
        crate::util::prop_check("skimquery-json-roundtrip", 40, |rng| {
            let mut q = SkimQuery::new(
                format!("in{}.troot", rng.below(10)),
                format!("out{}.troot", rng.below(10)),
            );
            q.selection = gen_selection(rng);
            q.force_all = rng.chance(0.3);
            q.branches = (0..1 + rng.below(4)).map(|i| format!("B{i}_*")).collect();
            if rng.chance(0.7) {
                let cuts = [
                    "nMuon >= 2",
                    "MET_pt > 100 || sum(Jet_pt[Jet_pt > 30]) > 250",
                    "abs(Muon_eta) < 2.4 && count(Jet_pt > 45) >= 2",
                    "max(Muon_pt) > 52 || !(HLT_Mu50)",
                ];
                q = q.with_cut_str(cuts[rng.below(cuts.len() as u32) as usize]).unwrap();
            }
            let text = q.to_json().to_string();
            let back = SkimQuery::from_json_text(&text)
                .unwrap_or_else(|e| panic!("reparse failed for {text}: {e}"));
            assert_eq!(back, q, "payload={text}");
        });
    }
}
