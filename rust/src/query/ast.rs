//! Query schema: the structured JSON selection format of Figure 2c.
//!
//! A query names the input dataset, the output file, the branches to
//! keep (with wildcards), and a **multi-stage selection**:
//!
//! 1. *preselection* — cheap single-branch scalar cuts ("at least one
//!    high-quality lepton"), evaluated first to discard events early;
//! 2. *object-level* — per-particle kinematic/ID cuts over jagged
//!    collections (electrons, muons, jets) with a minimum surviving
//!    multiplicity;
//! 3. *event-level* — composite variables: HT (scalar sum of jet pT
//!    above a threshold) and a trigger OR.
//!
//! Example payload:
//!
//! ```json
//! {
//!   "input": "store/higgs.troot",
//!   "output": "skim.troot",
//!   "branches": ["Electron_*", "Muon_*", "Jet_pt", "MET_pt", "HLT_*"],
//!   "force_all": false,
//!   "selection": {
//!     "preselection": [ {"branch": "nElectron", "op": ">=", "value": 1} ],
//!     "objects": [
//!       { "collection": "Electron", "min_count": 1, "cuts": [
//!           {"var": "Electron_pt",  "op": ">",   "value": 25.0},
//!           {"var": "Electron_eta", "op": "|<|", "value": 2.4} ] }
//!     ],
//!     "event": {
//!       "ht": {"jet_pt": "Jet_pt", "object_pt_min": 30.0, "min": 200.0},
//!       "triggers_any": ["HLT_IsoMu24", "HLT_Ele27_WPTight"]
//!     }
//!   }
//! }
//! ```

use super::json::Json;
use crate::{Error, Result};
use std::collections::BTreeMap;

/// Comparison operator. `AbsLt`/`AbsGt` compare `|x|` (the idiomatic
/// `|eta| < 2.4` acceptance cut).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Gt,
    Ge,
    Lt,
    Le,
    Eq,
    Ne,
    AbsLt,
    AbsGt,
}

impl CmpOp {
    pub fn parse(s: &str) -> Result<CmpOp> {
        Ok(match s {
            ">" => CmpOp::Gt,
            ">=" => CmpOp::Ge,
            "<" => CmpOp::Lt,
            "<=" => CmpOp::Le,
            "==" => CmpOp::Eq,
            "!=" => CmpOp::Ne,
            "|<|" => CmpOp::AbsLt,
            "|>|" => CmpOp::AbsGt,
            other => return Err(Error::query(format!("unknown operator '{other}'"))),
        })
    }

    pub fn symbol(self) -> &'static str {
        match self {
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Eq => "==",
            CmpOp::Ne => "!=",
            CmpOp::AbsLt => "|<|",
            CmpOp::AbsGt => "|>|",
        }
    }

    /// Apply the comparison.
    #[inline]
    pub fn eval(self, x: f64, v: f64) -> bool {
        match self {
            CmpOp::Gt => x > v,
            CmpOp::Ge => x >= v,
            CmpOp::Lt => x < v,
            CmpOp::Le => x <= v,
            CmpOp::Eq => x == v,
            CmpOp::Ne => x != v,
            CmpOp::AbsLt => x.abs() < v,
            CmpOp::AbsGt => x.abs() > v,
        }
    }

    /// Numeric opcode for the AOT kernel's cut bank (must match
    /// `python/compile/kernels/skim.py`).
    pub fn code(self) -> (u8, bool) {
        match self {
            CmpOp::Gt => (0, false),
            CmpOp::Ge => (1, false),
            CmpOp::Lt => (2, false),
            CmpOp::Le => (3, false),
            CmpOp::Eq => (4, false),
            CmpOp::Ne => (5, false),
            CmpOp::AbsLt => (2, true),
            CmpOp::AbsGt => (0, true),
        }
    }
}

/// Scalar-branch cut (preselection stage).
#[derive(Debug, Clone, PartialEq)]
pub struct ScalarCut {
    pub branch: String,
    pub op: CmpOp,
    pub value: f64,
}

/// Per-object cut over one jagged variable.
#[derive(Debug, Clone, PartialEq)]
pub struct ObjectCut {
    pub var: String,
    pub op: CmpOp,
    pub value: f64,
}

/// Object-level selection: an event passes if at least `min_count`
/// objects of `collection` satisfy **all** `cuts`.
#[derive(Debug, Clone, PartialEq)]
pub struct ObjectSelection {
    pub collection: String,
    pub cuts: Vec<ObjectCut>,
    pub min_count: u32,
}

/// HT cut: scalar sum of `jet_pt` over objects with pT above
/// `object_pt_min` must be at least `min`.
#[derive(Debug, Clone, PartialEq)]
pub struct HtCut {
    pub jet_pt: String,
    pub object_pt_min: f64,
    pub min: f64,
}

/// Event-level selection: composite variables + trigger OR.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct EventSelection {
    pub ht: Option<HtCut>,
    /// Event passes if **any** listed trigger flag is set. Empty = no
    /// trigger requirement.
    pub triggers_any: Vec<String>,
}

/// The full multi-stage selection.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Selection {
    pub preselection: Vec<ScalarCut>,
    pub objects: Vec<ObjectSelection>,
    pub event: EventSelection,
}

impl Selection {
    /// All branches the selection reads (the *filtering criteria*
    /// branches of §3.1).
    pub fn referenced_branches(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        let mut push = |name: &str| {
            if !out.iter().any(|n| n == name) {
                out.push(name.to_string());
            }
        };
        for c in &self.preselection {
            push(&c.branch);
        }
        for sel in &self.objects {
            for c in &sel.cuts {
                push(&c.var);
            }
        }
        if let Some(ht) = &self.event.ht {
            push(&ht.jet_pt);
        }
        for t in &self.event.triggers_any {
            push(t);
        }
        out
    }

    pub fn is_empty(&self) -> bool {
        self.preselection.is_empty()
            && self.objects.is_empty()
            && self.event.ht.is_none()
            && self.event.triggers_any.is_empty()
    }
}

/// A complete skim request.
#[derive(Debug, Clone, PartialEq)]
pub struct SkimQuery {
    /// Catalog-relative path of the input file.
    pub input: String,
    /// Output file name for the filtered result.
    pub output: String,
    /// Branch patterns to keep in the output (wildcards allowed).
    pub branches: Vec<String>,
    /// Disable the curated wildcard mapping (§3.1): expand patterns
    /// against the *full* schema.
    pub force_all: bool,
    pub selection: Selection,
}

impl SkimQuery {
    /// Parse and validate a JSON query payload.
    pub fn from_json_text(text: &str) -> Result<SkimQuery> {
        Self::from_json(&Json::parse(text)?)
    }

    pub fn from_json(v: &Json) -> Result<SkimQuery> {
        let input = v.str_field("input")?.to_string();
        if input.is_empty() {
            return Err(Error::query("'input' must not be empty"));
        }
        let output = v.str_field("output")?.to_string();
        if output.is_empty() {
            return Err(Error::query("'output' must not be empty"));
        }
        let branches = match v.get("branches") {
            Some(Json::Arr(items)) => items
                .iter()
                .map(|b| {
                    b.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| Error::query("'branches' entries must be strings"))
                })
                .collect::<Result<Vec<_>>>()?,
            Some(_) => return Err(Error::query("'branches' must be an array")),
            None => vec!["*".to_string()],
        };
        let force_all = match v.get("force_all") {
            Some(Json::Bool(b)) => *b,
            Some(_) => return Err(Error::query("'force_all' must be a boolean")),
            None => false,
        };
        let selection = match v.get("selection") {
            Some(sel) => parse_selection(sel)?,
            None => Selection::default(),
        };
        Ok(SkimQuery { input, output, branches, force_all, selection })
    }

    /// Serialize back to the canonical JSON payload (used to POST the
    /// query to the DPU and to hash job ids).
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("input".into(), Json::Str(self.input.clone()));
        obj.insert("output".into(), Json::Str(self.output.clone()));
        obj.insert(
            "branches".into(),
            Json::Arr(self.branches.iter().map(|b| Json::Str(b.clone())).collect()),
        );
        obj.insert("force_all".into(), Json::Bool(self.force_all));
        let mut sel = BTreeMap::new();
        sel.insert(
            "preselection".into(),
            Json::Arr(
                self.selection
                    .preselection
                    .iter()
                    .map(|c| {
                        let mut m = BTreeMap::new();
                        m.insert("branch".into(), Json::Str(c.branch.clone()));
                        m.insert("op".into(), Json::Str(c.op.symbol().into()));
                        m.insert("value".into(), Json::Num(c.value));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        sel.insert(
            "objects".into(),
            Json::Arr(
                self.selection
                    .objects
                    .iter()
                    .map(|s| {
                        let mut m = BTreeMap::new();
                        m.insert("collection".into(), Json::Str(s.collection.clone()));
                        m.insert("min_count".into(), Json::Num(s.min_count as f64));
                        m.insert(
                            "cuts".into(),
                            Json::Arr(
                                s.cuts
                                    .iter()
                                    .map(|c| {
                                        let mut m = BTreeMap::new();
                                        m.insert("var".into(), Json::Str(c.var.clone()));
                                        m.insert("op".into(), Json::Str(c.op.symbol().into()));
                                        m.insert("value".into(), Json::Num(c.value));
                                        Json::Obj(m)
                                    })
                                    .collect(),
                            ),
                        );
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        let mut ev = BTreeMap::new();
        if let Some(ht) = &self.selection.event.ht {
            let mut m = BTreeMap::new();
            m.insert("jet_pt".into(), Json::Str(ht.jet_pt.clone()));
            m.insert("object_pt_min".into(), Json::Num(ht.object_pt_min));
            m.insert("min".into(), Json::Num(ht.min));
            ev.insert("ht".into(), Json::Obj(m));
        }
        if !self.selection.event.triggers_any.is_empty() {
            ev.insert(
                "triggers_any".into(),
                Json::Arr(
                    self.selection
                        .event
                        .triggers_any
                        .iter()
                        .map(|t| Json::Str(t.clone()))
                        .collect(),
                ),
            );
        }
        sel.insert("event".into(), Json::Obj(ev));
        obj.insert("selection".into(), Json::Obj(sel));
        Json::Obj(obj)
    }
}

fn parse_selection(v: &Json) -> Result<Selection> {
    let mut sel = Selection::default();
    if let Some(pre) = v.get("preselection") {
        let items = pre
            .as_arr()
            .ok_or_else(|| Error::query("'preselection' must be an array"))?;
        for item in items {
            sel.preselection.push(ScalarCut {
                branch: item.str_field("branch")?.to_string(),
                op: CmpOp::parse(item.str_field("op")?)?,
                value: item.num_field("value")?,
            });
        }
    }
    if let Some(objs) = v.get("objects") {
        let items = objs
            .as_arr()
            .ok_or_else(|| Error::query("'objects' must be an array"))?;
        for item in items {
            let collection = item.str_field("collection")?.to_string();
            let min_count = match item.get("min_count") {
                Some(n) => {
                    let f = n
                        .as_f64()
                        .ok_or_else(|| Error::query("'min_count' must be a number"))?;
                    if f < 0.0 || f.fract() != 0.0 {
                        return Err(Error::query("'min_count' must be a non-negative integer"));
                    }
                    f as u32
                }
                None => 1,
            };
            let cuts_json = item
                .require("cuts")?
                .as_arr()
                .ok_or_else(|| Error::query("'cuts' must be an array"))?;
            if cuts_json.is_empty() {
                return Err(Error::query(format!(
                    "object selection for '{collection}' has no cuts"
                )));
            }
            let mut cuts = Vec::new();
            for c in cuts_json {
                let var = c.str_field("var")?.to_string();
                if !var.starts_with(&format!("{collection}_")) {
                    return Err(Error::query(format!(
                        "cut variable '{var}' does not belong to collection '{collection}'"
                    )));
                }
                cuts.push(ObjectCut {
                    var,
                    op: CmpOp::parse(c.str_field("op")?)?,
                    value: c.num_field("value")?,
                });
            }
            sel.objects.push(ObjectSelection { collection, cuts, min_count });
        }
    }
    if let Some(ev) = v.get("event") {
        if let Some(ht) = ev.get("ht") {
            sel.event.ht = Some(HtCut {
                jet_pt: ht.str_field("jet_pt")?.to_string(),
                object_pt_min: ht.get("object_pt_min").and_then(|v| v.as_f64()).unwrap_or(0.0),
                min: ht.num_field("min")?,
            });
        }
        if let Some(trig) = ev.get("triggers_any") {
            let items = trig
                .as_arr()
                .ok_or_else(|| Error::query("'triggers_any' must be an array"))?;
            for t in items {
                sel.event.triggers_any.push(
                    t.as_str()
                        .ok_or_else(|| Error::query("'triggers_any' entries must be strings"))?
                        .to_string(),
                );
            }
        }
    }
    Ok(sel)
}

#[cfg(test)]
mod tests {
    use super::*;

    pub const SAMPLE: &str = r#"{
        "input": "store/higgs.troot",
        "output": "skim.troot",
        "branches": ["Electron_*", "Muon_*", "Jet_pt", "MET_pt", "HLT_*"],
        "force_all": false,
        "selection": {
            "preselection": [ {"branch": "nElectron", "op": ">=", "value": 1} ],
            "objects": [
                { "collection": "Electron", "min_count": 1, "cuts": [
                    {"var": "Electron_pt",  "op": ">",   "value": 25.0},
                    {"var": "Electron_eta", "op": "|<|", "value": 2.4} ] }
            ],
            "event": {
                "ht": {"jet_pt": "Jet_pt", "object_pt_min": 30.0, "min": 200.0},
                "triggers_any": ["HLT_IsoMu24", "HLT_Ele27_WPTight"]
            }
        }
    }"#;

    #[test]
    fn parses_full_query() {
        let q = SkimQuery::from_json_text(SAMPLE).unwrap();
        assert_eq!(q.input, "store/higgs.troot");
        assert_eq!(q.branches.len(), 5);
        assert!(!q.force_all);
        assert_eq!(q.selection.preselection.len(), 1);
        assert_eq!(q.selection.objects[0].cuts.len(), 2);
        assert_eq!(q.selection.objects[0].min_count, 1);
        let ht = q.selection.event.ht.as_ref().unwrap();
        assert_eq!(ht.min, 200.0);
        assert_eq!(q.selection.event.triggers_any.len(), 2);
    }

    #[test]
    fn json_roundtrip() {
        let q = SkimQuery::from_json_text(SAMPLE).unwrap();
        let text = q.to_json().to_string();
        let q2 = SkimQuery::from_json_text(&text).unwrap();
        assert_eq!(q, q2);
    }

    #[test]
    fn referenced_branches_cover_all_stages() {
        let q = SkimQuery::from_json_text(SAMPLE).unwrap();
        let refs = q.selection.referenced_branches();
        for b in ["nElectron", "Electron_pt", "Electron_eta", "Jet_pt", "HLT_IsoMu24"] {
            assert!(refs.iter().any(|r| r == b), "missing {b}");
        }
        // deduplicated
        let mut sorted = refs.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), refs.len());
    }

    #[test]
    fn defaults_apply() {
        let q = SkimQuery::from_json_text(
            r#"{"input": "a.troot", "output": "b.troot"}"#,
        )
        .unwrap();
        assert_eq!(q.branches, vec!["*"]);
        assert!(!q.force_all);
        assert!(q.selection.is_empty());
    }

    #[test]
    fn rejects_invalid_queries() {
        for bad in [
            r#"{"output": "b"}"#,                                   // no input
            r#"{"input": "", "output": "b"}"#,                      // empty input
            r#"{"input": "a", "output": "b", "branches": "x"}"#,    // branches not array
            r#"{"input": "a", "output": "b", "force_all": 1}"#,     // force_all not bool
            r#"{"input": "a", "output": "b", "selection": {"preselection": [{"branch": "x", "op": "~", "value": 1}]}}"#,
            r#"{"input": "a", "output": "b", "selection": {"objects": [{"collection": "El", "cuts": []}]}}"#,
            r#"{"input": "a", "output": "b", "selection": {"objects": [{"collection": "El", "cuts": [{"var": "Mu_pt", "op": ">", "value": 1}]}]}}"#,
            r#"{"input": "a", "output": "b", "selection": {"objects": [{"collection": "El", "min_count": -1, "cuts": [{"var": "El_pt", "op": ">", "value": 1}]}]}}"#,
        ] {
            assert!(SkimQuery::from_json_text(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn cmp_op_eval_semantics() {
        assert!(CmpOp::Gt.eval(2.0, 1.0));
        assert!(!CmpOp::Gt.eval(1.0, 1.0));
        assert!(CmpOp::Ge.eval(1.0, 1.0));
        assert!(CmpOp::AbsLt.eval(-2.0, 2.4));
        assert!(!CmpOp::AbsLt.eval(-3.0, 2.4));
        assert!(CmpOp::AbsGt.eval(-3.0, 2.4));
        assert!(CmpOp::Ne.eval(1.0, 2.0));
        for op in [CmpOp::Gt, CmpOp::Ge, CmpOp::Lt, CmpOp::Le, CmpOp::Eq, CmpOp::Ne, CmpOp::AbsLt, CmpOp::AbsGt] {
            assert_eq!(CmpOp::parse(op.symbol()).unwrap(), op);
        }
    }
}
