//! `troot` file writer.
//!
//! Buffers whole columns, then writes baskets **cluster-interleaved**:
//! for every event range of `basket_events`, one basket per branch (in
//! schema order) before moving to the next range — the layout ROOT
//! produces as events stream in, and the reason per-branch reads are
//! non-contiguous on disk.

use super::{basket, BranchDesc, BranchMeta, BasketInfo, ColumnData, FileMeta, MAGIC};
use crate::compress::{self, Codec};
use crate::{Error, Result};
use std::io::Write;

/// Writer for a single troot file.
pub struct TRootWriter {
    path: std::path::PathBuf,
    codec: Codec,
    basket_events: u32,
    columns: Vec<(BranchDesc, ColumnData)>,
    n_events: Option<u64>,
}

/// Summary returned by [`TRootWriter::finalize`].
#[derive(Debug, Clone)]
pub struct WriteSummary {
    /// Events written.
    pub n_events: u64,
    /// Branches written.
    pub n_branches: usize,
    /// Baskets written across all branches.
    pub n_baskets: usize,
    /// Uncompressed payload bytes.
    pub raw_bytes: u64,
    /// Final file size on disk.
    pub file_bytes: u64,
    /// Zone-map index of the written file, derived for free while the
    /// columns were in memory (callers persist it with
    /// [`crate::index::FileIndex::save`] next to the data file).
    pub index: crate::index::FileIndex,
}

impl WriteSummary {
    /// `raw_bytes / file_bytes` (0.0 for an empty file).
    pub fn compression_ratio(&self) -> f64 {
        if self.file_bytes == 0 {
            return 0.0;
        }
        self.raw_bytes as f64 / self.file_bytes as f64
    }
}

impl TRootWriter {
    /// A writer targeting `path`, compressing every basket with
    /// `codec`, `basket_events` events per basket.
    pub fn new(path: impl Into<std::path::PathBuf>, codec: Codec, basket_events: u32) -> Self {
        assert!(basket_events > 0, "basket_events must be positive");
        TRootWriter {
            path: path.into(),
            codec,
            basket_events,
            columns: Vec::new(),
            n_events: None,
        }
    }

    /// Add a branch with its full column. All branches must agree on the
    /// event count; jagged descriptors must carry a non-empty group.
    pub fn add_branch(&mut self, desc: BranchDesc, data: ColumnData) -> Result<()> {
        if desc.kind != data.kind() {
            return Err(Error::format(format!(
                "branch {}: descriptor kind {:?} != data kind {:?}",
                desc.name,
                desc.kind,
                data.kind()
            )));
        }
        if desc.dtype != data.dtype() {
            return Err(Error::format(format!(
                "branch {}: descriptor dtype {:?} != data dtype {:?}",
                desc.name, desc.dtype,
                data.dtype()
            )));
        }
        if desc.kind == super::BranchKind::Jagged && desc.group.is_empty() {
            return Err(Error::format(format!(
                "jagged branch {} must declare a collection group",
                desc.name
            )));
        }
        if self.columns.iter().any(|(d, _)| d.name == desc.name) {
            return Err(Error::format(format!("duplicate branch {}", desc.name)));
        }
        let n = data.n_events() as u64;
        match self.n_events {
            None => self.n_events = Some(n),
            Some(prev) if prev != n => {
                return Err(Error::format(format!(
                    "branch {} has {n} events, file has {prev}",
                    desc.name
                )))
            }
            _ => {}
        }
        self.columns.push((desc, data));
        Ok(())
    }

    /// Write the file: magic, cluster-interleaved baskets, metadata,
    /// trailer. Consumes the writer.
    pub fn finalize(self) -> Result<WriteSummary> {
        let n_events = self.n_events.unwrap_or(0);
        let file = std::fs::File::create(&self.path)?;
        let mut w = std::io::BufWriter::new(file);
        w.write_all(MAGIC)?;
        let mut offset = MAGIC.len() as u64;

        let mut metas: Vec<BranchMeta> = self
            .columns
            .iter()
            .map(|(desc, _)| BranchMeta { desc: desc.clone(), baskets: Vec::new() })
            .collect();

        let mut zones: Vec<crate::index::BranchZones> = self
            .columns
            .iter()
            .map(|(desc, _)| crate::index::BranchZones {
                name: desc.name.clone(),
                baskets: Vec::new(),
            })
            .collect();
        let mut raw_bytes = 0u64;
        let mut n_baskets = 0usize;
        let mut lo = 0u64;
        while lo < n_events {
            let hi = (lo + self.basket_events as u64).min(n_events);
            for (bi, (_, data)) in self.columns.iter().enumerate() {
                let raw = basket::encode(data, lo as usize, hi as usize);
                let frame = compress::compress(self.codec, &raw);
                w.write_all(&frame)?;
                metas[bi].baskets.push(BasketInfo {
                    offset,
                    comp_len: frame.len() as u32,
                    raw_len: raw.len() as u32,
                    first_event: lo,
                    n_events: (hi - lo) as u32,
                });
                zones[bi]
                    .baskets
                    .push(crate::index::summarize(data, lo as usize, hi as usize));
                offset += frame.len() as u64;
                raw_bytes += raw.len() as u64;
                n_baskets += 1;
            }
            lo = hi;
        }

        let meta = FileMeta {
            n_events,
            codec: self.codec,
            basket_events: self.basket_events,
            branches: metas,
        };
        let meta_offset = offset;
        let meta_bytes = encode_meta(&meta);
        w.write_all(&meta_bytes)?;
        w.write_all(&meta_offset.to_le_bytes())?;
        w.write_all(MAGIC)?;
        w.flush()?;

        let file_bytes = meta_offset + meta_bytes.len() as u64 + super::TRAILER_LEN as u64;
        let index = crate::index::FileIndex {
            digest: crate::index::meta_digest(&meta),
            n_events,
            basket_events: self.basket_events,
            branches: zones,
        };
        Ok(WriteSummary {
            n_events,
            n_branches: meta.branches.len(),
            n_baskets,
            raw_bytes,
            file_bytes,
            index,
        })
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    let b = s.as_bytes();
    assert!(b.len() <= u16::MAX as usize, "string too long for metadata");
    out.extend_from_slice(&(b.len() as u16).to_le_bytes());
    out.extend_from_slice(b);
}

/// Serialize file metadata (compressed with zlib: metadata for ~1750
/// branches × many baskets is itself megabytes, and ROOT compresses its
/// streamer/key info too).
pub fn encode_meta(meta: &FileMeta) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&1u32.to_le_bytes()); // version
    out.extend_from_slice(&meta.n_events.to_le_bytes());
    out.push(meta.codec.id());
    out.extend_from_slice(&meta.basket_events.to_le_bytes());
    out.extend_from_slice(&(meta.branches.len() as u32).to_le_bytes());
    for b in &meta.branches {
        put_str(&mut out, &b.desc.name);
        out.push(b.desc.dtype.id());
        out.push(match b.desc.kind {
            super::BranchKind::Scalar => 0,
            super::BranchKind::Jagged => 1,
        });
        put_str(&mut out, &b.desc.group);
        out.extend_from_slice(&(b.baskets.len() as u32).to_le_bytes());
        for k in &b.baskets {
            out.extend_from_slice(&k.offset.to_le_bytes());
            out.extend_from_slice(&k.comp_len.to_le_bytes());
            out.extend_from_slice(&k.raw_len.to_le_bytes());
            out.extend_from_slice(&k.first_event.to_le_bytes());
            out.extend_from_slice(&k.n_events.to_le_bytes());
        }
    }
    compress::compress(Codec::Zlib, &out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::troot::{BranchKind, ColumnValues, DType};

    #[test]
    fn rejects_mismatched_event_counts() {
        let dir = std::env::temp_dir().join("troot_w1");
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = TRootWriter::new(dir.join("a.troot"), Codec::None, 10);
        w.add_branch(
            BranchDesc::scalar("a", DType::F32),
            ColumnData::scalar_f32(vec![1.0; 5]),
        )
        .unwrap();
        let err = w.add_branch(
            BranchDesc::scalar("b", DType::F32),
            ColumnData::scalar_f32(vec![1.0; 6]),
        );
        assert!(err.is_err());
    }

    #[test]
    fn rejects_duplicate_and_mismatched_branches() {
        let dir = std::env::temp_dir().join("troot_w2");
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = TRootWriter::new(dir.join("b.troot"), Codec::None, 10);
        w.add_branch(
            BranchDesc::scalar("a", DType::F32),
            ColumnData::scalar_f32(vec![1.0; 5]),
        )
        .unwrap();
        assert!(w
            .add_branch(
                BranchDesc::scalar("a", DType::F32),
                ColumnData::scalar_f32(vec![1.0; 5]),
            )
            .is_err());
        // dtype mismatch
        assert!(w
            .add_branch(
                BranchDesc::scalar("c", DType::F64),
                ColumnData::scalar_f32(vec![1.0; 5]),
            )
            .is_err());
        // kind mismatch
        assert!(w
            .add_branch(
                BranchDesc::jagged("d", DType::F32, "D"),
                ColumnData::scalar_f32(vec![1.0; 5]),
            )
            .is_err());
        // jagged without group
        assert!(w
            .add_branch(
                BranchDesc {
                    name: "e".into(),
                    dtype: DType::F32,
                    kind: BranchKind::Jagged,
                    group: String::new(),
                },
                ColumnData::Jagged {
                    offsets: vec![0, 1, 2, 3, 4, 5],
                    values: ColumnValues::F32(vec![0.0; 5]),
                },
            )
            .is_err());
    }

    #[test]
    fn summary_counts_baskets() {
        let dir = std::env::temp_dir().join("troot_w3");
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = TRootWriter::new(dir.join("c.troot"), Codec::Lz4, 4);
        for name in ["a", "b", "c"] {
            w.add_branch(
                BranchDesc::scalar(name, DType::F32),
                ColumnData::scalar_f32((0..10).map(|i| i as f32).collect()),
            )
            .unwrap();
        }
        let s = w.finalize().unwrap();
        assert_eq!(s.n_events, 10);
        assert_eq!(s.n_branches, 3);
        // 10 events, 4 per basket → 3 clusters × 3 branches.
        assert_eq!(s.n_baskets, 9);
        assert!(s.file_bytes > 0);
    }
}
