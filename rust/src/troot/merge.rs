//! Deterministic merge of per-part skim outputs into one troot file —
//! the data-plane half of the dataset layer.
//!
//! Both multi-part execution paths end here: the multi-DPU fan-out
//! ([`crate::dpu::DpuCluster`]) merges event-range shards, and the
//! dataset coordinator ([`crate::coordinator`]) merges per-file skim
//! outputs. Parts are concatenated **in the caller-given order**
//! (shard order = event order; dataset order = resolved file order),
//! whole columns at a time: scalar columns append values, jagged
//! columns rebase offsets. The output is written with the first
//! part's codec and basket size, branch-by-branch in the first
//! part's schema order — so the merged bytes are a pure function of
//! the ordered part contents, independent of which part *finished*
//! first. The dataset tests cross-check this against a serial
//! single-file loop, byte for byte.

use super::{ColumnData, LocalFile, ReadAt, TRootReader, TRootWriter};
use crate::troot::writer::WriteSummary;
use crate::{Error, Result};
use std::path::Path;

/// In-memory [`ReadAt`] store over one part's output bytes.
pub struct MemStore(pub Vec<u8>);

impl ReadAt for MemStore {
    fn read_at(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
        let o = offset as usize;
        self.0
            .get(o..o + len)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error::format("mem store read out of bounds"))
    }

    fn size(&self) -> Result<u64> {
        Ok(self.0.len() as u64)
    }
}

/// Concatenate whole columns in part order (scalar: append values;
/// jagged: rebase offsets).
pub fn concat_columns(cols: Vec<ColumnData>) -> Result<ColumnData> {
    let mut iter = cols.into_iter();
    let mut acc = iter
        .next()
        .ok_or_else(|| Error::Engine("concat of zero columns".into()))?;
    for col in iter {
        match (&mut acc, col) {
            (ColumnData::Scalar(a), ColumnData::Scalar(b)) => {
                let n = b.len();
                a.extend_from_range(&b, 0..n);
            }
            (
                ColumnData::Jagged { offsets, values },
                ColumnData::Jagged { offsets: bo, values: bv },
            ) => {
                let base = *offsets.last().unwrap_or(&0);
                for &o in &bo[1..] {
                    offsets.push(base + o);
                }
                let n = bv.len();
                values.extend_from_range(&bv, 0..n);
            }
            _ => return Err(Error::Engine("part column kind mismatch".into())),
        }
    }
    Ok(acc)
}

/// Concatenate already-opened part readers, in order, into one troot
/// file at `out_path`. All parts must share the first part's branch
/// schema (names, kinds and dtypes, in order — checked up front so a
/// heterogeneous dataset errors instead of panicking mid-append); the
/// merged file inherits its codec and basket size.
pub fn concat_readers<R: ReadAt>(
    readers: &[TRootReader<R>],
    out_path: impl AsRef<Path>,
) -> Result<WriteSummary> {
    let first = readers
        .first()
        .ok_or_else(|| Error::Engine("merge of zero parts".into()))?;
    let meta0 = first.meta().clone();
    for (i, r) in readers.iter().enumerate().skip(1) {
        let m = r.meta();
        if m.branches.len() != meta0.branches.len()
            || m.branches.iter().zip(&meta0.branches).any(|(a, b)| {
                a.desc.name != b.desc.name
                    || a.desc.kind != b.desc.kind
                    || a.desc.dtype != b.desc.dtype
            })
        {
            return Err(Error::Engine(format!(
                "dataset part {i} schema mismatch: parts must share one \
                 branch schema to merge"
            )));
        }
    }
    let mut writer = TRootWriter::new(out_path.as_ref(), meta0.codec, meta0.basket_events);
    for b in &meta0.branches {
        let cols: Vec<ColumnData> = readers
            .iter()
            .map(|r| r.read_branch_all(&b.desc.name))
            .collect::<Result<Vec<_>>>()?;
        writer.add_branch(b.desc.clone(), concat_columns(cols)?)?;
    }
    writer.finalize()
}

/// Concatenate in-memory part outputs (in order) into one troot file.
pub fn concat_buffers(
    parts: Vec<Vec<u8>>,
    out_path: impl AsRef<Path>,
) -> Result<WriteSummary> {
    let readers: Vec<TRootReader<MemStore>> = parts
        .into_iter()
        .map(|p| TRootReader::open(MemStore(p)))
        .collect::<Result<Vec<_>>>()?;
    concat_readers(&readers, out_path)
}

/// Concatenate on-disk part files (in order) into one troot file.
pub fn concat_files(
    parts: &[impl AsRef<Path>],
    out_path: impl AsRef<Path>,
) -> Result<WriteSummary> {
    let readers: Vec<TRootReader<LocalFile>> = parts
        .iter()
        .map(|p| TRootReader::open(LocalFile::open(p)?))
        .collect::<Result<Vec<_>>>()?;
    concat_readers(&readers, out_path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::troot::{BranchDesc, ColumnValues, DType};

    fn part(path: &Path, scalars: &[f32], jagged: &[Vec<f32>]) -> Vec<u8> {
        let mut w = TRootWriter::new(path, Codec::Lz4, 2);
        w.add_branch(
            BranchDesc::scalar("MET_pt", DType::F32),
            ColumnData::Scalar(ColumnValues::F32(scalars.to_vec())),
        )
        .unwrap();
        w.add_branch(
            BranchDesc::jagged("Jet_pt", DType::F32, "Jet"),
            ColumnData::jagged_f32(jagged),
        )
        .unwrap();
        w.finalize().unwrap();
        std::fs::read(path).unwrap()
    }

    fn dir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("troot_merge_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn concat_rebases_jagged_offsets() {
        let a = ColumnData::jagged_f32(&[vec![1.0, 2.0], vec![3.0]]);
        let b = ColumnData::jagged_f32(&[vec![], vec![4.0, 5.0]]);
        let merged = concat_columns(vec![a, b]).unwrap();
        match merged {
            ColumnData::Jagged { offsets, values } => {
                assert_eq!(offsets, vec![0, 2, 3, 3, 5]);
                assert_eq!(values.len(), 5);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn merge_is_order_determined_not_completion_determined() {
        let d = dir();
        let a = part(&d.join("a.troot"), &[1.0, 2.0], &[vec![9.0], vec![]]);
        let b = part(&d.join("b.troot"), &[3.0], &[vec![7.0, 8.0]]);
        let out1 = d.join("m1.troot");
        let out2 = d.join("m2.troot");
        concat_buffers(vec![a.clone(), b.clone()], &out1).unwrap();
        // Same part order again — e.g. after parts completed in the
        // opposite order and were re-sorted by index — same bytes.
        concat_buffers(vec![a.clone(), b.clone()], &out2).unwrap();
        assert_eq!(std::fs::read(&out1).unwrap(), std::fs::read(&out2).unwrap());
        let r = TRootReader::open(LocalFile::open(&out1).unwrap()).unwrap();
        assert_eq!(r.n_events(), 3);
        // Different part order is a *different* dataset: bytes differ.
        let out3 = d.join("m3.troot");
        concat_buffers(vec![b, a], &out3).unwrap();
        assert_ne!(std::fs::read(&out1).unwrap(), std::fs::read(&out3).unwrap());
    }

    #[test]
    fn merge_rejects_schema_mismatch_and_zero_parts() {
        let d = dir();
        let a = part(&d.join("s1.troot"), &[1.0], &[vec![]]);
        let mut w = TRootWriter::new(d.join("s2.troot"), Codec::Lz4, 2);
        w.add_branch(
            BranchDesc::scalar("Other_pt", DType::F32),
            ColumnData::Scalar(ColumnValues::F32(vec![1.0])),
        )
        .unwrap();
        w.add_branch(
            BranchDesc::jagged("Jet_pt", DType::F32, "Jet"),
            ColumnData::jagged_f32(&[vec![]]),
        )
        .unwrap();
        w.finalize().unwrap();
        let b = std::fs::read(d.join("s2.troot")).unwrap();
        let err = concat_buffers(vec![a.clone(), b], d.join("bad.troot")).unwrap_err();
        assert!(format!("{err}").contains("schema mismatch"), "{err}");
        assert!(concat_buffers(Vec::new(), d.join("none.troot")).is_err());

        // Same names and kinds but a different element type must also
        // error (not panic inside the column append).
        let mut w = TRootWriter::new(d.join("s3.troot"), Codec::Lz4, 2);
        w.add_branch(
            BranchDesc::scalar("MET_pt", DType::I32),
            ColumnData::Scalar(ColumnValues::I32(vec![7])),
        )
        .unwrap();
        w.add_branch(
            BranchDesc::jagged("Jet_pt", DType::F32, "Jet"),
            ColumnData::jagged_f32(&[vec![]]),
        )
        .unwrap();
        w.finalize().unwrap();
        let c = std::fs::read(d.join("s3.troot")).unwrap();
        let err = concat_buffers(vec![a, c], d.join("bad2.troot")).unwrap_err();
        assert!(format!("{err}").contains("schema mismatch"), "{err}");
    }

    #[test]
    fn disk_and_memory_paths_agree() {
        let d = dir();
        let a = part(&d.join("f1.troot"), &[1.0, 2.0], &[vec![1.0], vec![2.0]]);
        let _ = part(&d.join("f2.troot"), &[4.0], &[vec![]]);
        let out_mem = d.join("out_mem.troot");
        let out_disk = d.join("out_disk.troot");
        let b = std::fs::read(d.join("f2.troot")).unwrap();
        concat_buffers(vec![a, b], &out_mem).unwrap();
        concat_files(&[d.join("f1.troot"), d.join("f2.troot")], &out_disk).unwrap();
        assert_eq!(std::fs::read(&out_mem).unwrap(), std::fs::read(&out_disk).unwrap());
    }
}
