//! `troot` file reader.
//!
//! Reads through a [`ReadAt`] abstraction so the *same* reader code runs
//! against a local file (server-side filtering), a remote XRootD-like
//! client (client-side filtering), or the DPU's PCIe path — only the
//! transport underneath changes, exactly as in the paper's comparison.
//!
//! Fetch, decompress and deserialize are **separate calls** so callers
//! (the engine, via `metrics`) can time each stage independently —
//! producing the paper's Figure 4b/5a operation breakdown.

use super::{basket, writer, BranchMeta, DecodedBasket, FileMeta, MAGIC, TRAILER_LEN};
use crate::compress;
use crate::{Error, Result};
use std::sync::Arc;

/// Positioned-read abstraction over any byte store.
pub trait ReadAt: Send + Sync {
    /// Read exactly `len` bytes at `offset`.
    fn read_at(&self, offset: u64, len: usize) -> Result<Vec<u8>>;

    /// Vector read: fetch many `(offset, len)` ranges in one request.
    /// The default coalesces adjacent/overlapping ranges into single
    /// `read_at` calls ([`coalesce_ranges`]) — fewer syscalls on the
    /// phase-2 gather path against local files; transports with a real
    /// readv (XRootD) override this to batch round-trips instead.
    fn read_vec(&self, ranges: &[(u64, usize)]) -> Result<Vec<Vec<u8>>> {
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); ranges.len()];
        for span in coalesce_ranges(ranges) {
            let buf = self.read_at(span.offset, span.len)?;
            if let [i] = span.members[..] {
                // Sole member covering the whole span: hand the buffer
                // over without a copy.
                debug_assert_eq!((ranges[i].0, ranges[i].1), (span.offset, span.len));
                out[i] = buf;
                continue;
            }
            for &i in &span.members {
                let (o, l) = ranges[i];
                let start = (o - span.offset) as usize;
                out[i] = buf[start..start + l].to_vec();
            }
        }
        Ok(out)
    }

    /// Total size in bytes.
    fn size(&self) -> Result<u64>;
}

/// One coalesced read span covering several requested ranges.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoalescedSpan {
    /// Start offset of the merged read.
    pub offset: u64,
    /// Length of the merged read.
    pub len: usize,
    /// Indices (into the request slice) of the ranges this span covers.
    pub members: Vec<usize>,
}

/// Upper bound on a coalesced span (8 MiB): merging is about saving
/// syscalls/round-trips, not building one file-sized read whose bulk
/// buffer would double peak memory while members are copied out. A
/// single range larger than this still gets its own (uncapped) span.
pub const MAX_COALESCED_SPAN: usize = 8 << 20;

/// Merge adjacent/overlapping `(offset, len)` ranges into spans of at
/// most [`MAX_COALESCED_SPAN`] bytes. Requests may arrive in any order
/// and may duplicate; each span's `members` lets the caller slice
/// per-request views back out of one bulk read. Ranges separated by a
/// gap are *not* merged (no over-read).
pub fn coalesce_ranges(ranges: &[(u64, usize)]) -> Vec<CoalescedSpan> {
    let mut order: Vec<usize> = (0..ranges.len()).collect();
    order.sort_by_key(|&i| ranges[i]);
    let mut spans: Vec<CoalescedSpan> = Vec::new();
    for i in order {
        let (o, l) = ranges[i];
        match spans.last_mut() {
            // Adjacent or overlapping, and the union stays under the
            // cap: extend the open span. (An overlapping range that
            // would blow the cap starts a fresh span and re-reads the
            // overlap — correctness is per-member, spans are only an
            // I/O batching unit.)
            Some(span)
                if o <= span.offset + span.len as u64
                    && ((o + l as u64).max(span.offset + span.len as u64) - span.offset)
                        as usize
                        <= MAX_COALESCED_SPAN =>
            {
                let end = (o + l as u64).max(span.offset + span.len as u64);
                span.len = (end - span.offset) as usize;
                span.members.push(i);
            }
            _ => spans.push(CoalescedSpan { offset: o, len: l, members: vec![i] }),
        }
    }
    spans
}

/// Local file backend (server-side / DPU-local reads).
pub struct LocalFile {
    file: std::fs::File,
}

impl LocalFile {
    /// Open a file for positioned reads.
    pub fn open(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(LocalFile { file: std::fs::File::open(path)? })
    }
}

impl ReadAt for LocalFile {
    fn read_at(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
        use std::os::unix::fs::FileExt;
        let mut buf = vec![0u8; len];
        self.file.read_exact_at(&mut buf, offset)?;
        Ok(buf)
    }

    fn size(&self) -> Result<u64> {
        Ok(self.file.metadata()?.len())
    }
}

impl<T: ReadAt + ?Sized> ReadAt for Arc<T> {
    fn read_at(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
        (**self).read_at(offset, len)
    }
    fn read_vec(&self, ranges: &[(u64, usize)]) -> Result<Vec<Vec<u8>>> {
        (**self).read_vec(ranges)
    }
    fn size(&self) -> Result<u64> {
        (**self).size()
    }
}

/// Open troot file: parsed metadata + the backing store.
pub struct TRootReader<R: ReadAt> {
    store: R,
    meta: FileMeta,
}

impl<R: ReadAt> TRootReader<R> {
    /// Open: read trailer, then the metadata block ("reading the file
    /// header" step of §2.1 — one small read + one metadata read).
    pub fn open(store: R) -> Result<Self> {
        let size = store.size()?;
        if size < (MAGIC.len() + TRAILER_LEN) as u64 {
            return Err(Error::format("file too small to be a troot file"));
        }
        let trailer = store.read_at(size - TRAILER_LEN as u64, TRAILER_LEN)?;
        if &trailer[8..16] != MAGIC {
            return Err(Error::format("bad trailer magic (not a troot file?)"));
        }
        let meta_offset = u64::from_le_bytes(trailer[..8].try_into().unwrap());
        if meta_offset >= size - TRAILER_LEN as u64 {
            return Err(Error::format("metadata offset out of bounds"));
        }
        let meta_len = (size - TRAILER_LEN as u64 - meta_offset) as usize;
        let meta_bytes = store.read_at(meta_offset, meta_len)?;
        let meta = decode_meta(&meta_bytes)?;
        Ok(TRootReader { store, meta })
    }

    /// Parsed file metadata (schema + basket index).
    pub fn meta(&self) -> &FileMeta {
        &self.meta
    }

    /// The backing store.
    pub fn store(&self) -> &R {
        &self.store
    }

    /// Total events in the file.
    pub fn n_events(&self) -> u64 {
        self.meta.n_events
    }

    /// Branch lookup that errors on unknown names.
    pub fn branch(&self, name: &str) -> Result<&BranchMeta> {
        self.meta
            .branch(name)
            .ok_or_else(|| Error::format(format!("no such branch: {name}")))
    }

    /// Fetch the compressed frame of one basket (the "basket fetch"
    /// stage). No decompression happens here.
    pub fn fetch_basket(&self, branch: &BranchMeta, idx: usize) -> Result<Vec<u8>> {
        let info = &branch.baskets[idx];
        self.store.read_at(info.offset, info.comp_len as usize)
    }

    /// Decompress + deserialize a fetched frame into typed columns.
    ///
    /// The decompressed buffer is shared with the decoded basket:
    /// f32/i32 values are zero-copy views into it when aligned (the
    /// decoder falls back to copying otherwise).
    pub fn decode_basket(
        &self,
        branch: &BranchMeta,
        idx: usize,
        frame: &[u8],
    ) -> Result<DecodedBasket> {
        let info = &branch.baskets[idx];
        let raw: super::SharedBytes = std::sync::Arc::new(compress::decompress(frame)?);
        basket::decode_shared(
            &branch.desc,
            &raw,
            0,
            info.first_event,
            info.n_events as usize,
            idx,
        )
    }

    /// Convenience: fetch + decompress + deserialize one basket.
    pub fn read_basket(&self, branch: &BranchMeta, idx: usize) -> Result<DecodedBasket> {
        let frame = self.fetch_basket(branch, idx)?;
        self.decode_basket(branch, idx, &frame)
    }

    /// Read a whole branch into one column (tests / small files).
    pub fn read_branch_all(&self, name: &str) -> Result<super::ColumnData> {
        let branch = self.branch(name)?.clone();
        let mut values = super::ColumnValues::empty(branch.desc.dtype);
        let mut offsets: Vec<u32> = vec![0];
        for idx in 0..branch.baskets.len() {
            let dec = self.read_basket(&branch, idx)?;
            match branch.desc.kind {
                super::BranchKind::Scalar => {
                    values.extend_from_range(&dec.values, 0..dec.values.len());
                }
                super::BranchKind::Jagged => {
                    let base = *offsets.last().unwrap();
                    for w in dec.offsets.windows(2) {
                        offsets.push(base + w[1]);
                    }
                    values.extend_from_range(&dec.values, 0..dec.values.len());
                }
            }
        }
        Ok(match branch.desc.kind {
            super::BranchKind::Scalar => super::ColumnData::Scalar(values),
            super::BranchKind::Jagged => super::ColumnData::Jagged { offsets, values },
        })
    }
}

fn get_str(buf: &[u8], pos: &mut usize) -> Result<String> {
    let len = *buf
        .get(*pos..*pos + 2)
        .and_then(|b| Some(u16::from_le_bytes(b.try_into().ok()?)))
        .as_ref()
        .ok_or_else(|| Error::format("truncated string length"))? as usize;
    *pos += 2;
    let s = buf
        .get(*pos..*pos + len)
        .ok_or_else(|| Error::format("truncated string"))?;
    *pos += len;
    String::from_utf8(s.to_vec()).map_err(|_| Error::format("invalid utf-8 in metadata"))
}

macro_rules! get_num {
    ($buf:expr, $pos:expr, $ty:ty) => {{
        const N: usize = std::mem::size_of::<$ty>();
        let v = $buf
            .get(*$pos..*$pos + N)
            .map(|b| <$ty>::from_le_bytes(b.try_into().unwrap()))
            .ok_or_else(|| Error::format("truncated metadata"))?;
        *$pos += N;
        v
    }};
}

/// Parse the (zlib-framed) metadata block written by the writer.
pub fn decode_meta(bytes: &[u8]) -> Result<FileMeta> {
    let raw = compress::decompress(bytes)?;
    let buf = raw.as_slice();
    let pos = &mut 0usize;
    let version = get_num!(buf, pos, u32);
    if version != 1 {
        return Err(Error::format(format!("unsupported troot version {version}")));
    }
    let n_events = get_num!(buf, pos, u64);
    let codec = compress::Codec::from_id(get_num!(buf, pos, u8))?;
    let basket_events = get_num!(buf, pos, u32);
    let n_branches = get_num!(buf, pos, u32) as usize;
    let mut branches = Vec::with_capacity(n_branches);
    for _ in 0..n_branches {
        let name = get_str(buf, pos)?;
        let dtype = super::DType::from_id(get_num!(buf, pos, u8))?;
        let kind = match get_num!(buf, pos, u8) {
            0 => super::BranchKind::Scalar,
            1 => super::BranchKind::Jagged,
            k => return Err(Error::format(format!("bad branch kind {k}"))),
        };
        let group = get_str(buf, pos)?;
        let n_baskets = get_num!(buf, pos, u32) as usize;
        let mut baskets = Vec::with_capacity(n_baskets);
        for _ in 0..n_baskets {
            baskets.push(super::BasketInfo {
                offset: get_num!(buf, pos, u64),
                comp_len: get_num!(buf, pos, u32),
                raw_len: get_num!(buf, pos, u32),
                first_event: get_num!(buf, pos, u64),
                n_events: get_num!(buf, pos, u32),
            });
        }
        branches.push(BranchMeta {
            desc: super::BranchDesc { name, dtype, kind, group },
            baskets,
        });
    }
    Ok(FileMeta { n_events, codec, basket_events, branches })
}

// Re-export for writer tests and tooling.
pub use writer::encode_meta;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::troot::{BranchDesc, ColumnData, DType, TRootWriter};
    use crate::util::Pcg32;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("troot_reader_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn write_sample(path: &std::path::Path, codec: Codec, n: usize, basket_events: u32) {
        let mut rng = Pcg32::new(99);
        let mut w = TRootWriter::new(path, codec, basket_events);
        w.add_branch(
            BranchDesc::scalar("MET_pt", DType::F32),
            ColumnData::scalar_f32((0..n).map(|i| i as f32 * 0.5).collect()),
        )
        .unwrap();
        let per_event: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let m = rng.poisson(3.0) as usize;
                (0..m).map(|_| rng.exp(25.0) as f32).collect()
            })
            .collect();
        w.add_branch(
            BranchDesc::jagged("Electron_pt", DType::F32, "Electron"),
            ColumnData::jagged_f32(&per_event),
        )
        .unwrap();
        w.add_branch(
            BranchDesc::scalar("HLT_IsoMu24", DType::U8),
            ColumnData::Scalar(crate::troot::ColumnValues::U8(
                (0..n).map(|i| (i % 3 == 0) as u8).collect(),
            )),
        )
        .unwrap();
        w.finalize().unwrap();
    }

    #[test]
    fn roundtrip_all_codecs() {
        for codec in [Codec::None, Codec::Lz4, Codec::Zlib, Codec::XzLike] {
            let path = tmp(&format!("rt_{codec}.troot"));
            write_sample(&path, codec, 500, 64);
            let r = TRootReader::open(LocalFile::open(&path).unwrap()).unwrap();
            assert_eq!(r.n_events(), 500);
            assert_eq!(r.meta().codec, codec);
            assert_eq!(r.meta().branches.len(), 3);

            let met = r.read_branch_all("MET_pt").unwrap();
            match met {
                ColumnData::Scalar(v) => {
                    assert_eq!(v.len(), 500);
                    assert_eq!(v.get_as_f64(10), 5.0);
                }
                _ => unreachable!(),
            }

            // Jagged column re-assembles across basket boundaries.
            let ele = r.read_branch_all("Electron_pt").unwrap();
            assert_eq!(ele.n_events(), 500);
        }
    }

    #[test]
    fn per_basket_access_matches_full_read() {
        let path = tmp("per_basket.troot");
        write_sample(&path, Codec::Lz4, 300, 50);
        let r = TRootReader::open(LocalFile::open(&path).unwrap()).unwrap();
        let branch = r.branch("Electron_pt").unwrap().clone();
        assert_eq!(branch.baskets.len(), 6);
        let full = r.read_branch_all("Electron_pt").unwrap();
        let (offsets, values) = match &full {
            ColumnData::Jagged { offsets, values } => (offsets, values),
            _ => unreachable!(),
        };
        // Event 123 via direct basket access == via full column.
        let idx = branch.basket_for_event(123).unwrap();
        let dec = r.read_basket(&branch, idx).unwrap();
        let local = dec.jagged_range(123);
        let global = offsets[123] as usize..offsets[124] as usize;
        let got = &dec.values_f32()[local];
        let want: Vec<f32> = match values {
            crate::troot::ColumnValues::F32(v) => v[global].to_vec(),
            _ => unreachable!(),
        };
        assert_eq!(got, want.as_slice());
    }

    #[test]
    fn cluster_interleaved_layout() {
        // Consecutive baskets of the same branch must NOT be adjacent
        // when more than one branch exists (ROOT-like layout).
        let path = tmp("layout.troot");
        write_sample(&path, Codec::None, 200, 50);
        let r = TRootReader::open(LocalFile::open(&path).unwrap()).unwrap();
        let b = r.branch("MET_pt").unwrap();
        for w in b.baskets.windows(2) {
            assert!(
                w[1].offset > w[0].offset + w[0].comp_len as u64,
                "baskets of one branch should be separated by other branches"
            );
        }
    }

    #[test]
    fn coalescing_merges_adjacent_and_overlapping_only() {
        // Adjacent ranges merge.
        let spans = coalesce_ranges(&[(0, 10), (10, 5)]);
        assert_eq!(spans.len(), 1);
        assert_eq!((spans[0].offset, spans[0].len), (0, 15));
        assert_eq!(spans[0].members, vec![0, 1]);

        // Overlapping ranges merge to the union.
        let spans = coalesce_ranges(&[(0, 10), (5, 10)]);
        assert_eq!(spans.len(), 1);
        assert_eq!((spans[0].offset, spans[0].len), (0, 15));

        // A contained range does not extend the span.
        let spans = coalesce_ranges(&[(0, 20), (5, 5)]);
        assert_eq!(spans.len(), 1);
        assert_eq!((spans[0].offset, spans[0].len), (0, 20));

        // Gaps stay separate (no over-read).
        let spans = coalesce_ranges(&[(0, 10), (11, 5)]);
        assert_eq!(spans.len(), 2);

        // Out-of-order input: members carry original indices.
        let spans = coalesce_ranges(&[(20, 5), (0, 10), (10, 10)]);
        assert_eq!(spans.len(), 1);
        assert_eq!((spans[0].offset, spans[0].len), (0, 25));
        assert_eq!(spans[0].members, vec![1, 2, 0]);

        assert!(coalesce_ranges(&[]).is_empty());

        // The span cap splits runs of contiguous ranges instead of
        // growing one unbounded read; a single oversized range still
        // forms its own span.
        let big = MAX_COALESCED_SPAN as u64;
        let spans = coalesce_ranges(&[(0, MAX_COALESCED_SPAN), (big, 10)]);
        assert_eq!(spans.len(), 2, "cap must split: {spans:?}");
        let spans = coalesce_ranges(&[(0, MAX_COALESCED_SPAN + 5)]);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].len, MAX_COALESCED_SPAN + 5);
    }

    #[test]
    fn default_read_vec_coalesces_and_returns_input_order() {
        let path = tmp("readvec.troot");
        std::fs::write(&path, (0u8..=255).collect::<Vec<u8>>()).unwrap();
        let f = LocalFile::open(&path).unwrap();
        // Unsorted, adjacent, overlapping and gapped ranges: results
        // must line up with the request order and exact bytes.
        let ranges = [(50u64, 4usize), (0, 8), (8, 8), (12, 10), (100, 1)];
        let got = f.read_vec(&ranges).unwrap();
        assert_eq!(got.len(), ranges.len());
        for (&(o, l), bytes) in ranges.iter().zip(&got) {
            let expect: Vec<u8> = (o as u8..o as u8 + l as u8).collect();
            assert_eq!(bytes, &expect, "range ({o},{l})");
        }
    }

    #[test]
    fn open_rejects_garbage() {
        let path = tmp("garbage.bin");
        std::fs::write(&path, vec![0u8; 100]).unwrap();
        assert!(TRootReader::open(LocalFile::open(&path).unwrap()).is_err());
        let path2 = tmp("tiny.bin");
        std::fs::write(&path2, b"xx").unwrap();
        assert!(TRootReader::open(LocalFile::open(&path2).unwrap()).is_err());
    }

    #[test]
    fn missing_branch_is_error() {
        let path = tmp("missing.troot");
        write_sample(&path, Codec::None, 10, 5);
        let r = TRootReader::open(LocalFile::open(&path).unwrap()).unwrap();
        assert!(r.branch("Nope_pt").is_err());
    }

    #[test]
    fn empty_file_roundtrip() {
        let path = tmp("empty.troot");
        let w = TRootWriter::new(&path, Codec::Lz4, 16);
        w.finalize().unwrap();
        let r = TRootReader::open(LocalFile::open(&path).unwrap()).unwrap();
        assert_eq!(r.n_events(), 0);
        assert!(r.meta().branches.is_empty());
    }
}
