//! `troot` — a ROOT-like columnar event file format (the storage
//! substrate of §2.1).
//!
//! Mirrors the structural properties of ROOT's `TTree` that drive
//! skimming performance:
//!
//! * **columnar**: each *branch* (column) stores one particle property;
//! * **baskets**: consecutive entries of a branch are grouped and
//!   compressed into baskets — the unit of I/O and decompression;
//! * **first-event-index array**: per branch, the starting event id of
//!   every basket, so event → basket lookup is a binary search;
//! * **event offset array**: jagged baskets carry per-event offsets so
//!   an event's slice is directly addressable after decompression;
//! * **cluster-interleaved layout**: baskets of different branches for
//!   the same event range are written adjacently (as ROOT does), so
//!   reading *one* branch across events touches *non-contiguous* file
//!   regions — the access pattern TTreeCache exists to batch;
//! * **self-describing metadata**: a footer holds the schema (branch
//!   names, types, jaggedness, basket index) read at `open()`.
//!
//! File layout:
//!
//! ```text
//! [ 8B magic "TROOTv1\0" ]
//! [ basket frames ... (cluster-interleaved, each a compress::frame) ]
//! [ metadata block ]
//! [ 16B trailer: u64 metadata offset, 8B magic ]
//! ```

pub mod basket;
pub mod merge;
pub mod reader;
pub mod writer;

pub use basket::DecodedBasket;
pub use reader::{coalesce_ranges, CoalescedSpan, LocalFile, ReadAt, TRootReader};
pub use writer::TRootWriter;

use crate::{Error, Result};
use std::sync::Arc;

/// A shared, immutable decompressed-basket buffer.
///
/// The `Arc` keeps the heap allocation alive (and at a stable address)
/// for as long as any [`ValueView`] borrows from it, which is what
/// makes the zero-copy decode path sound: views reinterpret the bytes
/// in place instead of copying them element-wise.
pub type SharedBytes = Arc<Vec<u8>>;

/// A typed, zero-copy view over a sub-range of a [`SharedBytes`]
/// buffer.
///
/// Construction ([`ValueView::new`]) only succeeds when every
/// precondition of the reinterpret cast holds — little-endian target,
/// in-bounds range, and a start address aligned for `T` — so
/// [`ValueView::as_slice`] is safe to call. Callers that cannot meet
/// the preconditions fall back to the owned (copying) decode path.
pub struct ValueView<T> {
    buf: SharedBytes,
    /// Byte offset of the first element within `buf`.
    start: usize,
    /// Number of `T` elements viewed.
    len: usize,
    _marker: std::marker::PhantomData<T>,
}

impl<T: Copy> ValueView<T> {
    /// Build a view of `len` elements starting `start` bytes into
    /// `buf`, or `None` when the cast would be unsound (big-endian
    /// target, out-of-bounds range, or misaligned start address).
    ///
    /// Only plain-old-data element types for which every bit pattern
    /// is a valid value (`f32`, `i32`) are instantiated in this crate.
    pub fn new(buf: SharedBytes, start: usize, len: usize) -> Option<Self> {
        if !cfg!(target_endian = "little") {
            return None;
        }
        let bytes = len.checked_mul(std::mem::size_of::<T>())?;
        let end = start.checked_add(bytes)?;
        if end > buf.len() {
            return None;
        }
        if (buf.as_ptr() as usize + start) % std::mem::align_of::<T>() != 0 {
            return None;
        }
        Some(ValueView { buf, start, len, _marker: std::marker::PhantomData })
    }

    /// The viewed elements.
    pub fn as_slice(&self) -> &[T] {
        // SAFETY: `new` checked that the range is in bounds of `buf`,
        // that the start address is aligned for `T`, and that the
        // target is little-endian (so the raw LE bytes *are* the
        // in-memory representation). The `Arc` field keeps the heap
        // buffer alive and pinned for `self`'s lifetime, and the
        // buffer behind an `Arc<Vec<u8>>` is never mutated.
        unsafe {
            std::slice::from_raw_parts(self.buf.as_ptr().add(self.start) as *const T, self.len)
        }
    }

    /// Number of viewed elements.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the view covers no elements.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl<T> Clone for ValueView<T> {
    fn clone(&self) -> Self {
        ValueView {
            buf: Arc::clone(&self.buf),
            start: self.start,
            len: self.len,
            _marker: std::marker::PhantomData,
        }
    }
}

impl<T: Copy + std::fmt::Debug> std::fmt::Debug for ValueView<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.as_slice().iter()).finish()
    }
}

/// File magic, leading the file and closing the trailer.
pub const MAGIC: &[u8; 8] = b"TROOTv1\0";
/// Trailer size: u64 metadata offset + 8-byte magic.
pub const TRAILER_LEN: usize = 16;

/// Element type of a branch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 32-bit float (most kinematic variables).
    F32,
    /// 64-bit float.
    F64,
    /// 32-bit signed integer (ids, counts).
    I32,
    /// 64-bit signed integer (run/event numbers).
    I64,
    /// Booleans and trigger flags (stored as one byte, 0/1).
    U8,
}

impl DType {
    /// Element size in bytes.
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 | DType::I64 => 8,
            DType::U8 => 1,
        }
    }

    /// Stable metadata id.
    pub fn id(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::F64 => 1,
            DType::I32 => 2,
            DType::I64 => 3,
            DType::U8 => 4,
        }
    }

    /// Inverse of [`DType::id`].
    pub fn from_id(id: u8) -> Result<DType> {
        Ok(match id {
            0 => DType::F32,
            1 => DType::F64,
            2 => DType::I32,
            3 => DType::I64,
            4 => DType::U8,
            _ => return Err(Error::format(format!("unknown dtype id {id}"))),
        })
    }

    /// Human-readable name (`--explain` output, reports).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::I32 => "i32",
            DType::I64 => "i64",
            DType::U8 => "u8",
        }
    }
}

/// Scalar (one value per event) vs jagged (variable-length vector per
/// event, e.g. `Electron_pt` for all electrons in the event).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BranchKind {
    /// One value per event.
    Scalar,
    /// A variable-length vector per event.
    Jagged,
}

/// Static description of one branch.
#[derive(Debug, Clone, PartialEq)]
pub struct BranchDesc {
    /// NanoAOD-style name, e.g. `Electron_pt`, `HLT_IsoMu24`, `nJet`.
    pub name: String,
    /// Element type.
    pub dtype: DType,
    /// Scalar vs jagged.
    pub kind: BranchKind,
    /// Collection prefix for jagged branches (`Electron`, `Jet`, ...);
    /// empty for scalars. Jagged branches in the same group share their
    /// per-event multiplicity.
    pub group: String,
}

impl BranchDesc {
    /// A scalar (one value per event) branch.
    pub fn scalar(name: impl Into<String>, dtype: DType) -> Self {
        BranchDesc { name: name.into(), dtype, kind: BranchKind::Scalar, group: String::new() }
    }

    /// A jagged branch in collection `group`.
    pub fn jagged(name: impl Into<String>, dtype: DType, group: impl Into<String>) -> Self {
        BranchDesc { name: name.into(), dtype, kind: BranchKind::Jagged, group: group.into() }
    }
}

/// Location + extent of one basket within the file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BasketInfo {
    /// Absolute file offset of the compressed frame.
    pub offset: u64,
    /// Compressed frame length in bytes.
    pub comp_len: u32,
    /// Raw (decompressed) payload length in bytes.
    pub raw_len: u32,
    /// First event id stored in this basket (the per-branch
    /// "first event index array" of §2.1 is the vector of these).
    pub first_event: u64,
    /// Number of events in this basket.
    pub n_events: u32,
}

/// A branch plus its basket index, as recorded in file metadata.
#[derive(Debug, Clone)]
pub struct BranchMeta {
    /// Static description (name, type, kind, group).
    pub desc: BranchDesc,
    /// Location + extent of every basket, in event order.
    pub baskets: Vec<BasketInfo>,
}

impl BranchMeta {
    /// Index of the basket containing `event` (binary search over the
    /// first-event-index array).
    pub fn basket_for_event(&self, event: u64) -> Option<usize> {
        if self.baskets.is_empty() {
            return None;
        }
        let idx = match self.baskets.binary_search_by_key(&event, |b| b.first_event) {
            Ok(i) => i,
            Err(0) => return None,
            Err(i) => i - 1,
        };
        let b = &self.baskets[idx];
        if event < b.first_event + b.n_events as u64 {
            Some(idx)
        } else {
            None
        }
    }

    /// Indices of baskets overlapping the event range `[lo, hi)`.
    pub fn baskets_for_range(&self, lo: u64, hi: u64) -> std::ops::Range<usize> {
        if lo >= hi || self.baskets.is_empty() {
            return 0..0;
        }
        let start = match self.baskets.binary_search_by_key(&lo, |b| b.first_event) {
            Ok(i) => i,
            Err(0) => 0,
            Err(i) => {
                let prev = &self.baskets[i - 1];
                if lo < prev.first_event + prev.n_events as u64 {
                    i - 1
                } else {
                    i
                }
            }
        };
        let end = match self.baskets.binary_search_by_key(&hi, |b| b.first_event) {
            Ok(i) => i,
            Err(i) => i,
        };
        start..end.max(start)
    }

    /// Compressed bytes across all baskets of this branch.
    pub fn total_comp_bytes(&self) -> u64 {
        self.baskets.iter().map(|b| b.comp_len as u64).sum()
    }

    /// Decompressed bytes across all baskets of this branch.
    pub fn total_raw_bytes(&self) -> u64 {
        self.baskets.iter().map(|b| b.raw_len as u64).sum()
    }
}

/// Whole-file metadata (the "header" of §2.1; physically a footer).
#[derive(Debug, Clone)]
pub struct FileMeta {
    /// Total events in the file.
    pub n_events: u64,
    /// Codec every basket is compressed with.
    pub codec: crate::compress::Codec,
    /// Events per basket (cluster size).
    pub basket_events: u32,
    /// The schema: every branch with its basket index.
    pub branches: Vec<BranchMeta>,
}

impl FileMeta {
    /// Branch lookup by name.
    pub fn branch(&self, name: &str) -> Option<&BranchMeta> {
        self.branches.iter().find(|b| b.desc.name == name)
    }

    /// Schema position of `name`.
    pub fn branch_index(&self, name: &str) -> Option<usize> {
        self.branches.iter().position(|b| b.desc.name == name)
    }

    /// All branch names, in schema order.
    pub fn branch_names(&self) -> impl Iterator<Item = &str> {
        self.branches.iter().map(|b| b.desc.name.as_str())
    }
}

/// In-memory column values (input to the writer, output of the reader).
///
/// The `F32View`/`I32View` variants are zero-copy: they borrow the
/// decompressed basket buffer in place (see [`ValueView`]) instead of
/// materializing an element-wise copy. Equality is *logical* — an
/// owned column and a view over the same values compare equal — and
/// all accessors are variant-transparent, so downstream code treats
/// owned and borrowed columns identically.
#[derive(Debug, Clone)]
pub enum ColumnValues {
    /// 32-bit floats.
    F32(Vec<f32>),
    /// 64-bit floats.
    F64(Vec<f64>),
    /// 32-bit signed integers.
    I32(Vec<i32>),
    /// 64-bit signed integers.
    I64(Vec<i64>),
    /// Bytes (flags/booleans).
    U8(Vec<u8>),
    /// Zero-copy view of 32-bit floats over a shared basket buffer.
    F32View(ValueView<f32>),
    /// Zero-copy view of 32-bit integers over a shared basket buffer.
    I32View(ValueView<i32>),
}

impl PartialEq for ColumnValues {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (ColumnValues::F64(a), ColumnValues::F64(b)) => a == b,
            (ColumnValues::I64(a), ColumnValues::I64(b)) => a == b,
            (ColumnValues::U8(a), ColumnValues::U8(b)) => a == b,
            _ => {
                // F32/I32 compare logically across owned and view
                // variants (same float semantics as the old derived
                // impl: NaN != NaN).
                if let (Some(a), Some(b)) = (self.as_f32(), other.as_f32()) {
                    return a == b;
                }
                if let (Some(a), Some(b)) = (self.as_i32(), other.as_i32()) {
                    return a == b;
                }
                false
            }
        }
    }
}

impl ColumnValues {
    /// Number of stored values.
    pub fn len(&self) -> usize {
        match self {
            ColumnValues::F32(v) => v.len(),
            ColumnValues::F64(v) => v.len(),
            ColumnValues::I32(v) => v.len(),
            ColumnValues::I64(v) => v.len(),
            ColumnValues::U8(v) => v.len(),
            ColumnValues::F32View(v) => v.len(),
            ColumnValues::I32View(v) => v.len(),
        }
    }

    /// True when no values are stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The element type of this column.
    pub fn dtype(&self) -> DType {
        match self {
            ColumnValues::F32(_) | ColumnValues::F32View(_) => DType::F32,
            ColumnValues::F64(_) => DType::F64,
            ColumnValues::I32(_) | ColumnValues::I32View(_) => DType::I32,
            ColumnValues::I64(_) => DType::I64,
            ColumnValues::U8(_) => DType::U8,
        }
    }

    /// An empty column of the given type.
    pub fn empty(dtype: DType) -> Self {
        match dtype {
            DType::F32 => ColumnValues::F32(Vec::new()),
            DType::F64 => ColumnValues::F64(Vec::new()),
            DType::I32 => ColumnValues::I32(Vec::new()),
            DType::I64 => ColumnValues::I64(Vec::new()),
            DType::U8 => ColumnValues::U8(Vec::new()),
        }
    }

    /// The values as `&[f32]`, when this is an f32 column (owned or
    /// view).
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            ColumnValues::F32(v) => Some(v),
            ColumnValues::F32View(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    /// The values as `&[i32]`, when this is an i32 column (owned or
    /// view).
    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            ColumnValues::I32(v) => Some(v),
            ColumnValues::I32View(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    /// True when this column borrows a shared basket buffer instead of
    /// owning its values (zero-copy decode succeeded).
    pub fn is_borrowed(&self) -> bool {
        matches!(self, ColumnValues::F32View(_) | ColumnValues::I32View(_))
    }

    /// Value at `i` converted to f64 (uniform access for the scalar
    /// interpreter; typed access is via the enum arms).
    pub fn get_as_f64(&self, i: usize) -> f64 {
        match self {
            ColumnValues::F32(v) => v[i] as f64,
            ColumnValues::F64(v) => v[i],
            ColumnValues::I32(v) => v[i] as f64,
            ColumnValues::I64(v) => v[i] as f64,
            ColumnValues::U8(v) => v[i] as f64,
            ColumnValues::F32View(v) => v.as_slice()[i] as f64,
            ColumnValues::I32View(v) => v.as_slice()[i] as f64,
        }
    }

    /// Append element `i` of `src` (same dtype) to `self`.
    ///
    /// `self` must be an owned variant — accumulators never borrow.
    pub fn push_from(&mut self, src: &ColumnValues, i: usize) {
        match self {
            ColumnValues::F32(d) => d.push(src.as_f32().expect("push_from: dtype mismatch")[i]),
            ColumnValues::I32(d) => d.push(src.as_i32().expect("push_from: dtype mismatch")[i]),
            ColumnValues::F64(d) => match src {
                ColumnValues::F64(s) => d.push(s[i]),
                _ => panic!("push_from: dtype mismatch"),
            },
            ColumnValues::I64(d) => match src {
                ColumnValues::I64(s) => d.push(s[i]),
                _ => panic!("push_from: dtype mismatch"),
            },
            ColumnValues::U8(d) => match src {
                ColumnValues::U8(s) => d.push(s[i]),
                _ => panic!("push_from: dtype mismatch"),
            },
            _ => panic!("push_from: destination must be owned"),
        }
    }

    /// Append a sub-range of `src` (same dtype) to `self`.
    ///
    /// `self` must be an owned variant — accumulators never borrow.
    pub fn extend_from_range(&mut self, src: &ColumnValues, range: std::ops::Range<usize>) {
        match self {
            ColumnValues::F32(d) => {
                let s = src.as_f32().expect("extend_from_range: dtype mismatch");
                d.extend_from_slice(&s[range]);
            }
            ColumnValues::I32(d) => {
                let s = src.as_i32().expect("extend_from_range: dtype mismatch");
                d.extend_from_slice(&s[range]);
            }
            ColumnValues::F64(d) => match src {
                ColumnValues::F64(s) => d.extend_from_slice(&s[range]),
                _ => panic!("extend_from_range: dtype mismatch"),
            },
            ColumnValues::I64(d) => match src {
                ColumnValues::I64(s) => d.extend_from_slice(&s[range]),
                _ => panic!("extend_from_range: dtype mismatch"),
            },
            ColumnValues::U8(d) => match src {
                ColumnValues::U8(s) => d.extend_from_slice(&s[range]),
                _ => panic!("extend_from_range: dtype mismatch"),
            },
            _ => panic!("extend_from_range: destination must be owned"),
        }
    }
}

/// A full column: scalar values or jagged values with per-event offsets.
#[derive(Debug, Clone, PartialEq)]
pub enum ColumnData {
    /// One value per event.
    Scalar(ColumnValues),
    /// `offsets.len() == n_events + 1`; event `i` owns
    /// `values[offsets[i]..offsets[i+1]]`.
    Jagged {
        /// Per-event offsets into `values` (n_events + 1 entries).
        offsets: Vec<u32>,
        /// The concatenated per-object values.
        values: ColumnValues,
    },
}

impl ColumnData {
    /// Number of events this column covers.
    pub fn n_events(&self) -> usize {
        match self {
            ColumnData::Scalar(v) => v.len(),
            ColumnData::Jagged { offsets, .. } => offsets.len().saturating_sub(1),
        }
    }

    /// Scalar vs jagged.
    pub fn kind(&self) -> BranchKind {
        match self {
            ColumnData::Scalar(_) => BranchKind::Scalar,
            ColumnData::Jagged { .. } => BranchKind::Jagged,
        }
    }

    /// The element type.
    pub fn dtype(&self) -> DType {
        match self {
            ColumnData::Scalar(v) => v.dtype(),
            ColumnData::Jagged { values, .. } => values.dtype(),
        }
    }

    /// Build a jagged column from per-event vectors of f32.
    pub fn jagged_f32(per_event: &[Vec<f32>]) -> Self {
        let mut offsets = Vec::with_capacity(per_event.len() + 1);
        let mut values = Vec::new();
        offsets.push(0u32);
        for ev in per_event {
            values.extend_from_slice(ev);
            offsets.push(values.len() as u32);
        }
        ColumnData::Jagged { offsets, values: ColumnValues::F32(values) }
    }

    /// Build a scalar f32 column.
    pub fn scalar_f32(values: Vec<f32>) -> Self {
        ColumnData::Scalar(ColumnValues::F32(values))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta_with_baskets(firsts_and_counts: &[(u64, u32)]) -> BranchMeta {
        BranchMeta {
            desc: BranchDesc::scalar("b", DType::F32),
            baskets: firsts_and_counts
                .iter()
                .map(|&(first_event, n_events)| BasketInfo {
                    offset: 0,
                    comp_len: 1,
                    raw_len: 1,
                    first_event,
                    n_events,
                })
                .collect(),
        }
    }

    #[test]
    fn basket_for_event_binary_search() {
        let m = meta_with_baskets(&[(0, 100), (100, 100), (200, 50)]);
        assert_eq!(m.basket_for_event(0), Some(0));
        assert_eq!(m.basket_for_event(99), Some(0));
        assert_eq!(m.basket_for_event(100), Some(1));
        assert_eq!(m.basket_for_event(199), Some(1));
        assert_eq!(m.basket_for_event(200), Some(2));
        assert_eq!(m.basket_for_event(249), Some(2));
        assert_eq!(m.basket_for_event(250), None);
        assert_eq!(m.basket_for_event(9999), None);
    }

    #[test]
    fn baskets_for_range_spans() {
        let m = meta_with_baskets(&[(0, 100), (100, 100), (200, 50)]);
        assert_eq!(m.baskets_for_range(0, 250), 0..3);
        assert_eq!(m.baskets_for_range(50, 150), 0..2);
        assert_eq!(m.baskets_for_range(100, 101), 1..2);
        assert_eq!(m.baskets_for_range(99, 100), 0..1);
        assert_eq!(m.baskets_for_range(10, 10), 0..0);
        assert_eq!(m.baskets_for_range(200, 500), 2..3);
    }

    #[test]
    fn empty_branch_lookups() {
        let m = meta_with_baskets(&[]);
        assert_eq!(m.basket_for_event(0), None);
        assert_eq!(m.baskets_for_range(0, 10), 0..0);
    }

    #[test]
    fn jagged_from_per_event() {
        let col = ColumnData::jagged_f32(&[vec![1.0, 2.0], vec![], vec![3.0]]);
        match &col {
            ColumnData::Jagged { offsets, values } => {
                assert_eq!(offsets, &[0, 2, 2, 3]);
                assert_eq!(values.len(), 3);
            }
            _ => unreachable!(),
        }
        assert_eq!(col.n_events(), 3);
    }

    #[test]
    fn dtype_roundtrip_ids() {
        for d in [DType::F32, DType::F64, DType::I32, DType::I64, DType::U8] {
            assert_eq!(DType::from_id(d.id()).unwrap(), d);
        }
        assert!(DType::from_id(99).is_err());
    }
}
