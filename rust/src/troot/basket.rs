//! Basket payload encoding: the raw (pre-compression) byte layout of one
//! basket, and its typed decode ("deserialization" in the paper's
//! breakdown — the step that turns basket bytes into usable columns).
//!
//! Raw layouts (little-endian):
//!
//! * scalar basket: `values[n_events]`
//! * jagged basket: `u32 offsets[n_events + 1]` (relative to the basket)
//!   followed by `values[offsets[n_events]]` — the "event offset array"
//!   of §2.1 that lets ROOT address one event's slice directly.

use super::{BranchDesc, BranchKind, ColumnValues, DType, SharedBytes, ValueView};
use crate::{Error, Result};

/// Encode a slice of a column (events `[lo, hi)` of `col`) into the raw
/// basket payload.
pub fn encode(col: &super::ColumnData, lo: usize, hi: usize) -> Vec<u8> {
    debug_assert!(lo <= hi && hi <= col.n_events());
    match col {
        super::ColumnData::Scalar(values) => {
            let mut out = Vec::new();
            encode_values_range(values, lo, hi, &mut out);
            out
        }
        super::ColumnData::Jagged { offsets, values } => {
            let v_lo = offsets[lo] as usize;
            let v_hi = offsets[hi] as usize;
            let n = hi - lo;
            let mut out = Vec::with_capacity(4 * (n + 1) + (v_hi - v_lo) * values.dtype().size());
            out.resize(4 * (n + 1), 0);
            fill_le_bytes(&mut out[..], &offsets[lo..=hi], |off| {
                (off - offsets[lo]).to_le_bytes()
            });
            encode_values_range(values, v_lo, v_hi, &mut out);
            out
        }
    }
}

/// Write `values[lo..hi]` as little-endian bytes appended to `out`:
/// the destination is sized up front and filled by per-element
/// fixed-width `copy_from_slice` chunks (no per-byte growth checks,
/// no iterator-of-bytes collect on the writer hot path).
fn encode_values_range(values: &ColumnValues, lo: usize, hi: usize, out: &mut Vec<u8>) {
    let base = out.len();
    let n = hi - lo;
    match values {
        ColumnValues::F32(v) => {
            out.resize(base + n * 4, 0);
            fill_le_bytes(&mut out[base..], &v[lo..hi], |x| x.to_le_bytes());
        }
        ColumnValues::F64(v) => {
            out.resize(base + n * 8, 0);
            fill_le_bytes(&mut out[base..], &v[lo..hi], |x| x.to_le_bytes());
        }
        ColumnValues::I32(v) => {
            out.resize(base + n * 4, 0);
            fill_le_bytes(&mut out[base..], &v[lo..hi], |x| x.to_le_bytes());
        }
        ColumnValues::I64(v) => {
            out.resize(base + n * 8, 0);
            fill_le_bytes(&mut out[base..], &v[lo..hi], |x| x.to_le_bytes());
        }
        ColumnValues::U8(v) => out.extend_from_slice(&v[lo..hi]),
        ColumnValues::F32View(v) => {
            out.resize(base + n * 4, 0);
            fill_le_bytes(&mut out[base..], &v.as_slice()[lo..hi], |x| x.to_le_bytes());
        }
        ColumnValues::I32View(v) => {
            out.resize(base + n * 4, 0);
            fill_le_bytes(&mut out[base..], &v.as_slice()[lo..hi], |x| x.to_le_bytes());
        }
    }
}

/// Fill `dst` with the fixed-width encodings of `src`, chunk by chunk.
#[inline]
fn fill_le_bytes<T: Copy, const N: usize>(dst: &mut [u8], src: &[T], enc: impl Fn(T) -> [u8; N]) {
    for (chunk, &x) in dst.chunks_exact_mut(N).zip(src) {
        chunk.copy_from_slice(&enc(x));
    }
}

/// A decoded (deserialized) basket: typed values plus, for jagged
/// branches, the per-event offset array.
#[derive(Debug, Clone)]
pub struct DecodedBasket {
    /// Global id of the first event in this basket.
    pub first_event: u64,
    /// Events covered by this basket.
    pub n_events: usize,
    /// Scalar vs jagged.
    pub kind: BranchKind,
    /// Present only for jagged baskets; `offsets.len() == n_events + 1`.
    pub offsets: Vec<u32>,
    /// The typed values (concatenated per-object for jagged baskets).
    pub values: ColumnValues,
}

impl DecodedBasket {
    /// Scalar value of the event with *global* id `event`, as f64.
    pub fn scalar_f64(&self, event: u64) -> f64 {
        debug_assert_eq!(self.kind, BranchKind::Scalar);
        let i = (event - self.first_event) as usize;
        self.values.get_as_f64(i)
    }

    /// Value range of global event `event` for jagged baskets.
    pub fn jagged_range(&self, event: u64) -> std::ops::Range<usize> {
        debug_assert_eq!(self.kind, BranchKind::Jagged);
        let i = (event - self.first_event) as usize;
        self.offsets[i] as usize..self.offsets[i + 1] as usize
    }

    /// Number of objects in global event `event` (jagged only).
    pub fn multiplicity(&self, event: u64) -> usize {
        let r = self.jagged_range(event);
        r.end - r.start
    }

    /// f32 view of the values (panics if the branch is not F32 — the
    /// vectorized engine only batches F32 columns).
    pub fn values_f32(&self) -> &[f32] {
        match self.values.as_f32() {
            Some(v) => v,
            None => panic!("values_f32 on {:?} branch", self.values.dtype()),
        }
    }
}

/// Decode a raw basket payload (`n_events` events starting at
/// `first_event`) according to `desc`, copying the values into owned
/// columns. `basket` is the basket's index within the branch, used
/// only to give decode errors a locus.
pub fn decode(
    desc: &BranchDesc,
    raw: &[u8],
    first_event: u64,
    n_events: usize,
    basket: usize,
) -> Result<DecodedBasket> {
    decode_impl(desc, raw, None, first_event, n_events, basket)
}

/// Decode a basket payload held in a shared decompressed buffer,
/// borrowing f32/i32 values in place (zero-copy) when the cast is
/// sound; the copying path of [`decode`] is the fallback for
/// misaligned payloads, exotic dtypes, and big-endian targets.
///
/// The payload is `buf[start..]`; jagged offset arrays are always
/// copied (they are validated and rebased), only the value bytes are
/// borrowed.
pub fn decode_shared(
    desc: &BranchDesc,
    buf: &SharedBytes,
    start: usize,
    first_event: u64,
    n_events: usize,
    basket: usize,
) -> Result<DecodedBasket> {
    if start > buf.len() {
        return Err(Error::format(format!(
            "branch {} basket {basket}: payload start {start} beyond buffer {}",
            desc.name,
            buf.len()
        )));
    }
    // Split the borrow: `raw` for validation, `(buf, start)` so the
    // value decoder can construct views into the shared buffer.
    let raw = &buf[start..];
    decode_impl(desc, raw, Some((buf, start)), first_event, n_events, basket)
}

fn decode_impl(
    desc: &BranchDesc,
    raw: &[u8],
    view: Option<(&SharedBytes, usize)>,
    first_event: u64,
    n_events: usize,
    basket: usize,
) -> Result<DecodedBasket> {
    match desc.kind {
        BranchKind::Scalar => {
            let expect = n_events * desc.dtype.size();
            if raw.len() != expect {
                return Err(Error::format(format!(
                    "branch {} basket {basket}: scalar basket payload {} != expected {expect}",
                    desc.name,
                    raw.len()
                )));
            }
            Ok(DecodedBasket {
                first_event,
                n_events,
                kind: BranchKind::Scalar,
                offsets: Vec::new(),
                values: decode_values(desc.dtype, raw, view)?,
            })
        }
        BranchKind::Jagged => {
            let head = 4 * (n_events + 1);
            if raw.len() < head {
                return Err(Error::format(format!(
                    "branch {} basket {basket}: jagged basket too short for offset array",
                    desc.name
                )));
            }
            let mut offsets = Vec::with_capacity(n_events + 1);
            for i in 0..=n_events {
                offsets.push(u32::from_le_bytes(raw[4 * i..4 * i + 4].try_into().unwrap()));
            }
            if offsets[0] != 0 {
                return Err(Error::format(format!(
                    "branch {} basket {basket}: event offset array starts at {} (expected 0)",
                    desc.name, offsets[0]
                )));
            }
            if let Some(i) = offsets.windows(2).position(|w| w[0] > w[1]) {
                return Err(Error::format(format!(
                    "branch {} basket {basket}: non-monotonic event offset array \
                     (offsets[{i}]={} > offsets[{}]={})",
                    desc.name,
                    offsets[i],
                    i + 1,
                    offsets[i + 1]
                )));
            }
            let n_values = *offsets.last().unwrap() as usize;
            let expect = head + n_values * desc.dtype.size();
            if raw.len() != expect {
                return Err(Error::format(format!(
                    "branch {} basket {basket}: jagged basket payload {} != expected {expect}",
                    desc.name,
                    raw.len()
                )));
            }
            Ok(DecodedBasket {
                first_event,
                n_events,
                kind: BranchKind::Jagged,
                offsets,
                values: decode_values(
                    desc.dtype,
                    &raw[head..],
                    view.map(|(buf, start)| (buf, start + head)),
                )?,
            })
        }
    }
}

/// Selectively deserialize **one event** of a raw basket payload,
/// appending its values (and, for jagged branches, the running offset)
/// to the output column.
///
/// This is the per-event `GetEntry` path: cost is proportional to the
/// *event's* data, not the basket's. SkimROOT's two-phase execution
/// uses it to deserialize output-only branches for passing events only
/// (the paper's 240.4 s → 16.8 s deserialization drop); the legacy
/// baseline decodes whole baskets instead.
pub fn append_event(
    desc: &BranchDesc,
    raw: &[u8],
    n_events: usize,
    local_idx: usize,
    offsets_out: &mut Vec<u32>,
    values_out: &mut ColumnValues,
) -> Result<()> {
    let sz = desc.dtype.size();
    let err = || Error::format(format!("branch {}: truncated basket payload", desc.name));
    match desc.kind {
        BranchKind::Scalar => {
            let start = local_idx * sz;
            let bytes = raw.get(start..start + sz).ok_or_else(err)?;
            push_value(desc.dtype, bytes, values_out);
            Ok(())
        }
        BranchKind::Jagged => {
            if local_idx + 1 > n_events {
                return Err(err());
            }
            let off = |i: usize| -> Result<usize> {
                raw.get(4 * i..4 * i + 4)
                    .map(|b| u32::from_le_bytes(b.try_into().unwrap()) as usize)
                    .ok_or_else(err)
            };
            let head = 4 * (n_events + 1);
            let lo = off(local_idx)?;
            let hi = off(local_idx + 1)?;
            if hi < lo {
                return Err(Error::format(format!(
                    "branch {}: non-monotonic event offsets",
                    desc.name
                )));
            }
            let start = head + lo * sz;
            let end = head + hi * sz;
            let bytes = raw.get(start..end).ok_or_else(err)?;
            for chunk in bytes.chunks_exact(sz) {
                push_value(desc.dtype, chunk, values_out);
            }
            offsets_out.push(values_out.len() as u32);
            Ok(())
        }
    }
}

fn push_value(dtype: DType, bytes: &[u8], out: &mut ColumnValues) {
    match (dtype, out) {
        (DType::F32, ColumnValues::F32(v)) => {
            v.push(f32::from_le_bytes(bytes.try_into().unwrap()))
        }
        (DType::F64, ColumnValues::F64(v)) => {
            v.push(f64::from_le_bytes(bytes.try_into().unwrap()))
        }
        (DType::I32, ColumnValues::I32(v)) => {
            v.push(i32::from_le_bytes(bytes.try_into().unwrap()))
        }
        (DType::I64, ColumnValues::I64(v)) => {
            v.push(i64::from_le_bytes(bytes.try_into().unwrap()))
        }
        (DType::U8, ColumnValues::U8(v)) => v.push(bytes[0]),
        _ => panic!("push_value: dtype/column mismatch"),
    }
}

/// Decode the value bytes of a basket. When `view` names the shared
/// buffer the bytes live in (and the byte offset of `raw` within it),
/// f32/i32 columns are returned as zero-copy [`ValueView`]s if the
/// buffer region is aligned for the element type on a little-endian
/// target; every other case copies, exactly as before.
fn decode_values(
    dtype: DType,
    raw: &[u8],
    view: Option<(&SharedBytes, usize)>,
) -> Result<ColumnValues> {
    let sz = dtype.size();
    if raw.len() % sz != 0 {
        return Err(Error::format("value bytes not a multiple of dtype size"));
    }
    if let Some((buf, start)) = view {
        debug_assert_eq!(&buf[start..start + raw.len()], raw);
        match dtype {
            DType::F32 => {
                if let Some(v) = ValueView::<f32>::new(buf.clone(), start, raw.len() / 4) {
                    return Ok(ColumnValues::F32View(v));
                }
            }
            DType::I32 => {
                if let Some(v) = ValueView::<i32>::new(buf.clone(), start, raw.len() / 4) {
                    return Ok(ColumnValues::I32View(v));
                }
            }
            _ => {}
        }
    }
    Ok(match dtype {
        DType::F32 => ColumnValues::F32(
            raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::F64 => ColumnValues::F64(
            raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::I32 => ColumnValues::I32(
            raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::I64 => ColumnValues::I64(
            raw.chunks_exact(8).map(|c| i64::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::U8 => ColumnValues::U8(raw.to_vec()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::troot::ColumnData;

    #[test]
    fn scalar_roundtrip() {
        let col = ColumnData::scalar_f32(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let desc = BranchDesc::scalar("x", DType::F32);
        let raw = encode(&col, 1, 4);
        let dec = decode(&desc, &raw, 1, 3, 0).unwrap();
        assert_eq!(dec.scalar_f64(1), 2.0);
        assert_eq!(dec.scalar_f64(3), 4.0);
        assert_eq!(dec.values_f32(), &[2.0, 3.0, 4.0]);
    }

    #[test]
    fn jagged_roundtrip() {
        let col = ColumnData::jagged_f32(&[
            vec![1.0, 2.0],
            vec![],
            vec![3.0, 4.0, 5.0],
            vec![6.0],
        ]);
        let desc = BranchDesc::jagged("Electron_pt", DType::F32, "Electron");
        // Slice events [1, 4): multiplicities 0, 3, 1.
        let raw = encode(&col, 1, 4);
        let dec = decode(&desc, &raw, 10, 3, 0).unwrap();
        assert_eq!(dec.multiplicity(10), 0);
        assert_eq!(dec.multiplicity(11), 3);
        assert_eq!(dec.multiplicity(12), 1);
        let r = dec.jagged_range(11);
        assert_eq!(&dec.values_f32()[r], &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn all_dtypes_roundtrip() {
        for (values, dtype) in [
            (ColumnValues::F64(vec![1.5, -2.5]), DType::F64),
            (ColumnValues::I32(vec![-7, 9]), DType::I32),
            (ColumnValues::I64(vec![1 << 40, -5]), DType::I64),
            (ColumnValues::U8(vec![0, 1]), DType::U8),
        ] {
            let col = ColumnData::Scalar(values.clone());
            let desc = BranchDesc::scalar("b", dtype);
            let raw = encode(&col, 0, 2);
            let dec = decode(&desc, &raw, 0, 2, 0).unwrap();
            assert_eq!(dec.values, values);
        }
    }

    #[test]
    fn preallocated_encoder_roundtrips_every_dtype_and_range() {
        // The chunk-filled writer path must reproduce the exact wire
        // bytes the byte-at-a-time path produced: encode arbitrary
        // sub-ranges of every dtype and decode them back.
        for (values, dtype) in [
            (ColumnValues::F32(vec![1.5, -2.25, 3.75, 0.0, 9.5]), DType::F32),
            (ColumnValues::F64(vec![1.5e10, -2.5, 0.125, 7.0, -0.5]), DType::F64),
            (ColumnValues::I32(vec![-7, 9, 1 << 30, 0, -1]), DType::I32),
            (ColumnValues::I64(vec![1 << 40, -5, 0, i64::MIN, i64::MAX]), DType::I64),
            (ColumnValues::U8(vec![0, 1, 255, 128, 7]), DType::U8),
        ] {
            let col = ColumnData::Scalar(values.clone());
            let desc = BranchDesc::scalar("b", dtype);
            for (lo, hi) in [(0usize, 5usize), (1, 4), (2, 2), (0, 1)] {
                let raw = encode(&col, lo, hi);
                assert_eq!(raw.len(), (hi - lo) * dtype.size());
                let dec = decode(&desc, &raw, lo as u64, hi - lo, 0).unwrap();
                let mut expect = ColumnValues::empty(dtype);
                expect.extend_from_range(&values, lo..hi);
                assert_eq!(dec.values, expect, "{dtype:?} [{lo},{hi})");
            }
        }

        // Jagged payloads: header offsets + values, sliced mid-column.
        let col = ColumnData::jagged_f32(&[
            vec![1.0],
            vec![2.0, 3.0, 4.0],
            vec![],
            vec![5.0, 6.0],
        ]);
        let desc = BranchDesc::jagged("j", DType::F32, "J");
        let raw = encode(&col, 1, 4);
        let dec = decode(&desc, &raw, 7, 3, 0).unwrap();
        assert_eq!(dec.offsets, vec![0, 3, 3, 5]);
        assert_eq!(dec.values_f32(), &[2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn decode_rejects_bad_sizes() {
        let desc = BranchDesc::scalar("x", DType::F32);
        assert!(decode(&desc, &[0u8; 7], 0, 2, 0).is_err()); // 2 events need 8B
        let jd = BranchDesc::jagged("j", DType::F32, "J");
        assert!(decode(&jd, &[0u8; 3], 0, 1, 0).is_err()); // too short for offsets
    }

    #[test]
    fn decode_rejects_non_monotonic_offsets() {
        let jd = BranchDesc::jagged("j", DType::F32, "J");
        // offsets [0, 2, 1] — decreasing.
        let mut raw = Vec::new();
        for o in [0u32, 2, 1] {
            raw.extend_from_slice(&o.to_le_bytes());
        }
        raw.extend_from_slice(&[0u8; 4]); // one f32
        assert!(decode(&jd, &raw, 0, 2, 0).is_err());
    }

    #[test]
    fn append_event_matches_full_decode() {
        let col = ColumnData::jagged_f32(&[vec![1.0, 2.0], vec![], vec![3.0, 4.0, 5.0]]);
        let desc = BranchDesc::jagged("j", DType::F32, "J");
        let raw = encode(&col, 0, 3);
        let mut offsets = vec![0u32];
        let mut values = ColumnValues::F32(Vec::new());
        for i in [0usize, 2] {
            append_event(&desc, &raw, 3, i, &mut offsets, &mut values).unwrap();
        }
        assert_eq!(offsets, vec![0, 2, 5]);
        assert_eq!(values, ColumnValues::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0]));

        // Scalars.
        let scol = ColumnData::scalar_f32(vec![10.0, 20.0, 30.0]);
        let sdesc = BranchDesc::scalar("s", DType::F32);
        let sraw = encode(&scol, 0, 3);
        let mut soff = Vec::new();
        let mut svals = ColumnValues::F32(Vec::new());
        append_event(&sdesc, &sraw, 3, 1, &mut soff, &mut svals).unwrap();
        assert_eq!(svals, ColumnValues::F32(vec![20.0]));
    }

    #[test]
    fn append_event_bounds_checked() {
        let desc = BranchDesc::scalar("s", DType::F32);
        let mut off = Vec::new();
        let mut vals = ColumnValues::F32(Vec::new());
        assert!(append_event(&desc, &[0u8; 4], 1, 1, &mut off, &mut vals).is_err());
        let jd = BranchDesc::jagged("j", DType::F32, "J");
        assert!(append_event(&jd, &[0u8; 3], 1, 0, &mut off, &mut vals).is_err());
    }

    #[test]
    fn empty_basket() {
        let col = ColumnData::scalar_f32(vec![]);
        let desc = BranchDesc::scalar("x", DType::F32);
        let raw = encode(&col, 0, 0);
        let dec = decode(&desc, &raw, 0, 0, 0).unwrap();
        assert_eq!(dec.values.len(), 0);
    }

    #[test]
    fn decode_errors_carry_basket_and_branch_locus() {
        let jd = BranchDesc::jagged("Jet_pt", DType::F32, "Jet");
        // offsets [0, 2, 1] — decreasing.
        let mut raw = Vec::new();
        for o in [0u32, 2, 1] {
            raw.extend_from_slice(&o.to_le_bytes());
        }
        raw.extend_from_slice(&[0u8; 4]);
        let err = decode(&jd, &raw, 0, 2, 17).unwrap_err().to_string();
        assert!(err.contains("Jet_pt"), "missing branch name: {err}");
        assert!(err.contains("basket 17"), "missing basket index: {err}");
        assert!(err.contains("offsets[1]=2"), "missing offending offsets: {err}");

        let sd = BranchDesc::scalar("nMuon", DType::I32);
        let err = decode(&sd, &[0u8; 7], 0, 2, 3).unwrap_err().to_string();
        assert!(err.contains("nMuon") && err.contains("basket 3"), "{err}");
    }

    // ------------------------------------------------------------------
    // Zero-copy decode (`decode_shared`): the unsafe reinterpret cast
    // lives behind `ValueView`; these tests (run under Miri in CI) pin
    // its soundness and the copy fallback.
    // ------------------------------------------------------------------

    #[test]
    fn decode_shared_borrows_aligned_f32_scalars() {
        let col = ColumnData::scalar_f32(vec![1.0, -2.5, 3.25, 0.0]);
        let desc = BranchDesc::scalar("x", DType::F32);
        let buf: SharedBytes = std::sync::Arc::new(encode(&col, 0, 4));
        let dec = decode_shared(&desc, &buf, 0, 0, 4, 0).unwrap();
        if cfg!(target_endian = "little") {
            assert!(dec.values.is_borrowed(), "aligned LE f32 payload should be viewed");
        }
        assert_eq!(dec.values_f32(), &[1.0, -2.5, 3.25, 0.0]);
        // The view and the owned decode agree exactly (logical eq).
        let owned = decode(&desc, &buf, 0, 4, 0).unwrap();
        assert!(!owned.values.is_borrowed());
        assert_eq!(dec.values, owned.values);
        // The view stays valid after the local Arc handle drops.
        drop(buf);
        assert_eq!(dec.values_f32()[1], -2.5);
    }

    #[test]
    fn decode_shared_borrows_i32_and_jagged_values() {
        let ints = ColumnData::Scalar(ColumnValues::I32(vec![-7, 42, 1 << 20]));
        let desc = BranchDesc::scalar("nJet", DType::I32);
        let buf: SharedBytes = std::sync::Arc::new(encode(&ints, 0, 3));
        let dec = decode_shared(&desc, &buf, 0, 0, 3, 0).unwrap();
        assert_eq!(dec.values.as_i32().unwrap(), &[-7, 42, 1 << 20]);
        if cfg!(target_endian = "little") {
            assert!(dec.values.is_borrowed());
        }

        // Jagged: the offset head is 4-byte, so the value region of an
        // f32 jagged basket is aligned whenever the buffer is.
        let col = ColumnData::jagged_f32(&[vec![1.0, 2.0], vec![], vec![3.0]]);
        let jd = BranchDesc::jagged("Electron_pt", DType::F32, "Electron");
        let jbuf: SharedBytes = std::sync::Arc::new(encode(&col, 0, 3));
        let jdec = decode_shared(&jd, &jbuf, 0, 0, 3, 0).unwrap();
        assert_eq!(jdec.offsets, vec![0, 2, 2, 3]);
        assert_eq!(jdec.values_f32(), &[1.0, 2.0, 3.0]);
        let r = jdec.jagged_range(2);
        assert_eq!(&jdec.values_f32()[r], &[3.0]);
    }

    #[test]
    fn decode_shared_falls_back_to_copy_on_odd_offset() {
        // Pad the payload by one byte so the value region is misaligned
        // for f32: the zero-copy gate must refuse the cast and the
        // copying path must produce identical values.
        let col = ColumnData::scalar_f32(vec![4.0, 5.5]);
        let payload = encode(&col, 0, 2);
        let mut padded = vec![0xAAu8];
        padded.extend_from_slice(&payload);
        let buf: SharedBytes = std::sync::Arc::new(padded);
        let desc = BranchDesc::scalar("x", DType::F32);
        let dec = decode_shared(&desc, &buf, 1, 0, 2, 0).unwrap();
        // One of the two start addresses (0 or 1 bytes into the heap
        // buffer) is necessarily misaligned for a 4-byte element; this
        // one may or may not be, depending on the allocator. Force the
        // question: whichever alignment the buffer got, values match.
        assert_eq!(dec.values_f32(), &[4.0, 5.5]);
        let aligned_start = (buf.as_ptr() as usize + 1) % std::mem::align_of::<f32>() == 0;
        assert_eq!(dec.values.is_borrowed(), aligned_start && cfg!(target_endian = "little"));

        // Deterministic misalignment: Vec<u8> allocations are at least
        // element-aligned, so among starts {0,1,2,3} exactly those with
        // (base + start) % 4 != 0 must copy. Check all four.
        let mut wide = Vec::new();
        for pad in 0..4usize {
            wide.clear();
            wide.extend(std::iter::repeat(0u8).take(pad));
            wide.extend_from_slice(&payload);
            let b: SharedBytes = std::sync::Arc::new(wide.clone());
            let d = decode_shared(&desc, &b, pad, 0, 2, 0).unwrap();
            assert_eq!(d.values_f32(), &[4.0, 5.5], "pad {pad}");
            let aligned = (b.as_ptr() as usize + pad) % 4 == 0;
            assert_eq!(
                d.values.is_borrowed(),
                aligned && cfg!(target_endian = "little"),
                "pad {pad}"
            );
        }
    }

    #[test]
    fn decode_shared_rejects_out_of_bounds_start() {
        let desc = BranchDesc::scalar("x", DType::F32);
        let buf: SharedBytes = std::sync::Arc::new(vec![0u8; 4]);
        assert!(decode_shared(&desc, &buf, 5, 0, 0, 0).is_err());
    }

    #[test]
    fn value_view_refuses_unsound_casts() {
        let buf: SharedBytes = std::sync::Arc::new(vec![0u8; 16]);
        // Out of bounds: 5 f32s need 20 bytes.
        assert!(ValueView::<f32>::new(buf.clone(), 0, 5).is_none());
        // Length overflow.
        assert!(ValueView::<f32>::new(buf.clone(), 0, usize::MAX).is_none());
        // In-bounds aligned view works (LE targets).
        if cfg!(target_endian = "little") {
            let v = ValueView::<f32>::new(buf, 0, 4).unwrap();
            assert_eq!(v.as_slice(), &[0.0; 4]);
            assert_eq!(v.len(), 4);
            assert!(!v.is_empty());
        }
    }
}
