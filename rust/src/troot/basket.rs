//! Basket payload encoding: the raw (pre-compression) byte layout of one
//! basket, and its typed decode ("deserialization" in the paper's
//! breakdown — the step that turns basket bytes into usable columns).
//!
//! Raw layouts (little-endian):
//!
//! * scalar basket: `values[n_events]`
//! * jagged basket: `u32 offsets[n_events + 1]` (relative to the basket)
//!   followed by `values[offsets[n_events]]` — the "event offset array"
//!   of §2.1 that lets ROOT address one event's slice directly.

use super::{BranchDesc, BranchKind, ColumnValues, DType};
use crate::{Error, Result};

/// Encode a slice of a column (events `[lo, hi)` of `col`) into the raw
/// basket payload.
pub fn encode(col: &super::ColumnData, lo: usize, hi: usize) -> Vec<u8> {
    debug_assert!(lo <= hi && hi <= col.n_events());
    match col {
        super::ColumnData::Scalar(values) => {
            let mut out = Vec::new();
            encode_values_range(values, lo, hi, &mut out);
            out
        }
        super::ColumnData::Jagged { offsets, values } => {
            let v_lo = offsets[lo] as usize;
            let v_hi = offsets[hi] as usize;
            let n = hi - lo;
            let mut out = Vec::with_capacity(4 * (n + 1) + (v_hi - v_lo) * values.dtype().size());
            out.resize(4 * (n + 1), 0);
            fill_le_bytes(&mut out[..], &offsets[lo..=hi], |off| {
                (off - offsets[lo]).to_le_bytes()
            });
            encode_values_range(values, v_lo, v_hi, &mut out);
            out
        }
    }
}

/// Write `values[lo..hi]` as little-endian bytes appended to `out`:
/// the destination is sized up front and filled by per-element
/// fixed-width `copy_from_slice` chunks (no per-byte growth checks,
/// no iterator-of-bytes collect on the writer hot path).
fn encode_values_range(values: &ColumnValues, lo: usize, hi: usize, out: &mut Vec<u8>) {
    let base = out.len();
    let n = hi - lo;
    match values {
        ColumnValues::F32(v) => {
            out.resize(base + n * 4, 0);
            fill_le_bytes(&mut out[base..], &v[lo..hi], |x| x.to_le_bytes());
        }
        ColumnValues::F64(v) => {
            out.resize(base + n * 8, 0);
            fill_le_bytes(&mut out[base..], &v[lo..hi], |x| x.to_le_bytes());
        }
        ColumnValues::I32(v) => {
            out.resize(base + n * 4, 0);
            fill_le_bytes(&mut out[base..], &v[lo..hi], |x| x.to_le_bytes());
        }
        ColumnValues::I64(v) => {
            out.resize(base + n * 8, 0);
            fill_le_bytes(&mut out[base..], &v[lo..hi], |x| x.to_le_bytes());
        }
        ColumnValues::U8(v) => out.extend_from_slice(&v[lo..hi]),
    }
}

/// Fill `dst` with the fixed-width encodings of `src`, chunk by chunk.
#[inline]
fn fill_le_bytes<T: Copy, const N: usize>(dst: &mut [u8], src: &[T], enc: impl Fn(T) -> [u8; N]) {
    for (chunk, &x) in dst.chunks_exact_mut(N).zip(src) {
        chunk.copy_from_slice(&enc(x));
    }
}

/// A decoded (deserialized) basket: typed values plus, for jagged
/// branches, the per-event offset array.
#[derive(Debug, Clone)]
pub struct DecodedBasket {
    /// Global id of the first event in this basket.
    pub first_event: u64,
    /// Events covered by this basket.
    pub n_events: usize,
    /// Scalar vs jagged.
    pub kind: BranchKind,
    /// Present only for jagged baskets; `offsets.len() == n_events + 1`.
    pub offsets: Vec<u32>,
    /// The typed values (concatenated per-object for jagged baskets).
    pub values: ColumnValues,
}

impl DecodedBasket {
    /// Scalar value of the event with *global* id `event`, as f64.
    pub fn scalar_f64(&self, event: u64) -> f64 {
        debug_assert_eq!(self.kind, BranchKind::Scalar);
        let i = (event - self.first_event) as usize;
        self.values.get_as_f64(i)
    }

    /// Value range of global event `event` for jagged baskets.
    pub fn jagged_range(&self, event: u64) -> std::ops::Range<usize> {
        debug_assert_eq!(self.kind, BranchKind::Jagged);
        let i = (event - self.first_event) as usize;
        self.offsets[i] as usize..self.offsets[i + 1] as usize
    }

    /// Number of objects in global event `event` (jagged only).
    pub fn multiplicity(&self, event: u64) -> usize {
        let r = self.jagged_range(event);
        r.end - r.start
    }

    /// f32 view of the values (panics if the branch is not F32 — the
    /// vectorized engine only batches F32 columns).
    pub fn values_f32(&self) -> &[f32] {
        match &self.values {
            ColumnValues::F32(v) => v,
            other => panic!("values_f32 on {:?} branch", other.dtype()),
        }
    }
}

/// Decode a raw basket payload (`n_events` events starting at
/// `first_event`) according to `desc`.
pub fn decode(
    desc: &BranchDesc,
    raw: &[u8],
    first_event: u64,
    n_events: usize,
) -> Result<DecodedBasket> {
    match desc.kind {
        BranchKind::Scalar => {
            let expect = n_events * desc.dtype.size();
            if raw.len() != expect {
                return Err(Error::format(format!(
                    "branch {}: scalar basket payload {} != expected {expect}",
                    desc.name,
                    raw.len()
                )));
            }
            Ok(DecodedBasket {
                first_event,
                n_events,
                kind: BranchKind::Scalar,
                offsets: Vec::new(),
                values: decode_values(desc.dtype, raw)?,
            })
        }
        BranchKind::Jagged => {
            let head = 4 * (n_events + 1);
            if raw.len() < head {
                return Err(Error::format(format!(
                    "branch {}: jagged basket too short for offset array",
                    desc.name
                )));
            }
            let mut offsets = Vec::with_capacity(n_events + 1);
            for i in 0..=n_events {
                offsets.push(u32::from_le_bytes(raw[4 * i..4 * i + 4].try_into().unwrap()));
            }
            if offsets[0] != 0 || offsets.windows(2).any(|w| w[0] > w[1]) {
                return Err(Error::format(format!(
                    "branch {}: non-monotonic event offset array",
                    desc.name
                )));
            }
            let n_values = *offsets.last().unwrap() as usize;
            let expect = head + n_values * desc.dtype.size();
            if raw.len() != expect {
                return Err(Error::format(format!(
                    "branch {}: jagged basket payload {} != expected {expect}",
                    desc.name,
                    raw.len()
                )));
            }
            Ok(DecodedBasket {
                first_event,
                n_events,
                kind: BranchKind::Jagged,
                offsets,
                values: decode_values(desc.dtype, &raw[head..])?,
            })
        }
    }
}

/// Selectively deserialize **one event** of a raw basket payload,
/// appending its values (and, for jagged branches, the running offset)
/// to the output column.
///
/// This is the per-event `GetEntry` path: cost is proportional to the
/// *event's* data, not the basket's. SkimROOT's two-phase execution
/// uses it to deserialize output-only branches for passing events only
/// (the paper's 240.4 s → 16.8 s deserialization drop); the legacy
/// baseline decodes whole baskets instead.
pub fn append_event(
    desc: &BranchDesc,
    raw: &[u8],
    n_events: usize,
    local_idx: usize,
    offsets_out: &mut Vec<u32>,
    values_out: &mut ColumnValues,
) -> Result<()> {
    let sz = desc.dtype.size();
    let err = || Error::format(format!("branch {}: truncated basket payload", desc.name));
    match desc.kind {
        BranchKind::Scalar => {
            let start = local_idx * sz;
            let bytes = raw.get(start..start + sz).ok_or_else(err)?;
            push_value(desc.dtype, bytes, values_out);
            Ok(())
        }
        BranchKind::Jagged => {
            if local_idx + 1 > n_events {
                return Err(err());
            }
            let off = |i: usize| -> Result<usize> {
                raw.get(4 * i..4 * i + 4)
                    .map(|b| u32::from_le_bytes(b.try_into().unwrap()) as usize)
                    .ok_or_else(err)
            };
            let head = 4 * (n_events + 1);
            let lo = off(local_idx)?;
            let hi = off(local_idx + 1)?;
            if hi < lo {
                return Err(Error::format(format!(
                    "branch {}: non-monotonic event offsets",
                    desc.name
                )));
            }
            let start = head + lo * sz;
            let end = head + hi * sz;
            let bytes = raw.get(start..end).ok_or_else(err)?;
            for chunk in bytes.chunks_exact(sz) {
                push_value(desc.dtype, chunk, values_out);
            }
            offsets_out.push(values_out.len() as u32);
            Ok(())
        }
    }
}

fn push_value(dtype: DType, bytes: &[u8], out: &mut ColumnValues) {
    match (dtype, out) {
        (DType::F32, ColumnValues::F32(v)) => {
            v.push(f32::from_le_bytes(bytes.try_into().unwrap()))
        }
        (DType::F64, ColumnValues::F64(v)) => {
            v.push(f64::from_le_bytes(bytes.try_into().unwrap()))
        }
        (DType::I32, ColumnValues::I32(v)) => {
            v.push(i32::from_le_bytes(bytes.try_into().unwrap()))
        }
        (DType::I64, ColumnValues::I64(v)) => {
            v.push(i64::from_le_bytes(bytes.try_into().unwrap()))
        }
        (DType::U8, ColumnValues::U8(v)) => v.push(bytes[0]),
        _ => panic!("push_value: dtype/column mismatch"),
    }
}

fn decode_values(dtype: DType, raw: &[u8]) -> Result<ColumnValues> {
    let sz = dtype.size();
    if raw.len() % sz != 0 {
        return Err(Error::format("value bytes not a multiple of dtype size"));
    }
    Ok(match dtype {
        DType::F32 => ColumnValues::F32(
            raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::F64 => ColumnValues::F64(
            raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::I32 => ColumnValues::I32(
            raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::I64 => ColumnValues::I64(
            raw.chunks_exact(8).map(|c| i64::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        DType::U8 => ColumnValues::U8(raw.to_vec()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::troot::ColumnData;

    #[test]
    fn scalar_roundtrip() {
        let col = ColumnData::scalar_f32(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let desc = BranchDesc::scalar("x", DType::F32);
        let raw = encode(&col, 1, 4);
        let dec = decode(&desc, &raw, 1, 3).unwrap();
        assert_eq!(dec.scalar_f64(1), 2.0);
        assert_eq!(dec.scalar_f64(3), 4.0);
        assert_eq!(dec.values_f32(), &[2.0, 3.0, 4.0]);
    }

    #[test]
    fn jagged_roundtrip() {
        let col = ColumnData::jagged_f32(&[
            vec![1.0, 2.0],
            vec![],
            vec![3.0, 4.0, 5.0],
            vec![6.0],
        ]);
        let desc = BranchDesc::jagged("Electron_pt", DType::F32, "Electron");
        // Slice events [1, 4): multiplicities 0, 3, 1.
        let raw = encode(&col, 1, 4);
        let dec = decode(&desc, &raw, 10, 3).unwrap();
        assert_eq!(dec.multiplicity(10), 0);
        assert_eq!(dec.multiplicity(11), 3);
        assert_eq!(dec.multiplicity(12), 1);
        let r = dec.jagged_range(11);
        assert_eq!(&dec.values_f32()[r], &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn all_dtypes_roundtrip() {
        for (values, dtype) in [
            (ColumnValues::F64(vec![1.5, -2.5]), DType::F64),
            (ColumnValues::I32(vec![-7, 9]), DType::I32),
            (ColumnValues::I64(vec![1 << 40, -5]), DType::I64),
            (ColumnValues::U8(vec![0, 1]), DType::U8),
        ] {
            let col = ColumnData::Scalar(values.clone());
            let desc = BranchDesc::scalar("b", dtype);
            let raw = encode(&col, 0, 2);
            let dec = decode(&desc, &raw, 0, 2).unwrap();
            assert_eq!(dec.values, values);
        }
    }

    #[test]
    fn preallocated_encoder_roundtrips_every_dtype_and_range() {
        // The chunk-filled writer path must reproduce the exact wire
        // bytes the byte-at-a-time path produced: encode arbitrary
        // sub-ranges of every dtype and decode them back.
        for (values, dtype) in [
            (ColumnValues::F32(vec![1.5, -2.25, 3.75, 0.0, 9.5]), DType::F32),
            (ColumnValues::F64(vec![1.5e10, -2.5, 0.125, 7.0, -0.5]), DType::F64),
            (ColumnValues::I32(vec![-7, 9, 1 << 30, 0, -1]), DType::I32),
            (ColumnValues::I64(vec![1 << 40, -5, 0, i64::MIN, i64::MAX]), DType::I64),
            (ColumnValues::U8(vec![0, 1, 255, 128, 7]), DType::U8),
        ] {
            let col = ColumnData::Scalar(values.clone());
            let desc = BranchDesc::scalar("b", dtype);
            for (lo, hi) in [(0usize, 5usize), (1, 4), (2, 2), (0, 1)] {
                let raw = encode(&col, lo, hi);
                assert_eq!(raw.len(), (hi - lo) * dtype.size());
                let dec = decode(&desc, &raw, lo as u64, hi - lo).unwrap();
                let mut expect = ColumnValues::empty(dtype);
                expect.extend_from_range(&values, lo..hi);
                assert_eq!(dec.values, expect, "{dtype:?} [{lo},{hi})");
            }
        }

        // Jagged payloads: header offsets + values, sliced mid-column.
        let col = ColumnData::jagged_f32(&[
            vec![1.0],
            vec![2.0, 3.0, 4.0],
            vec![],
            vec![5.0, 6.0],
        ]);
        let desc = BranchDesc::jagged("j", DType::F32, "J");
        let raw = encode(&col, 1, 4);
        let dec = decode(&desc, &raw, 7, 3).unwrap();
        assert_eq!(dec.offsets, vec![0, 3, 3, 5]);
        assert_eq!(dec.values_f32(), &[2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn decode_rejects_bad_sizes() {
        let desc = BranchDesc::scalar("x", DType::F32);
        assert!(decode(&desc, &[0u8; 7], 0, 2).is_err()); // 2 events need 8B
        let jd = BranchDesc::jagged("j", DType::F32, "J");
        assert!(decode(&jd, &[0u8; 3], 0, 1).is_err()); // too short for offsets
    }

    #[test]
    fn decode_rejects_non_monotonic_offsets() {
        let jd = BranchDesc::jagged("j", DType::F32, "J");
        // offsets [0, 2, 1] — decreasing.
        let mut raw = Vec::new();
        for o in [0u32, 2, 1] {
            raw.extend_from_slice(&o.to_le_bytes());
        }
        raw.extend_from_slice(&[0u8; 4]); // one f32
        assert!(decode(&jd, &raw, 0, 2).is_err());
    }

    #[test]
    fn append_event_matches_full_decode() {
        let col = ColumnData::jagged_f32(&[vec![1.0, 2.0], vec![], vec![3.0, 4.0, 5.0]]);
        let desc = BranchDesc::jagged("j", DType::F32, "J");
        let raw = encode(&col, 0, 3);
        let mut offsets = vec![0u32];
        let mut values = ColumnValues::F32(Vec::new());
        for i in [0usize, 2] {
            append_event(&desc, &raw, 3, i, &mut offsets, &mut values).unwrap();
        }
        assert_eq!(offsets, vec![0, 2, 5]);
        assert_eq!(values, ColumnValues::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0]));

        // Scalars.
        let scol = ColumnData::scalar_f32(vec![10.0, 20.0, 30.0]);
        let sdesc = BranchDesc::scalar("s", DType::F32);
        let sraw = encode(&scol, 0, 3);
        let mut soff = Vec::new();
        let mut svals = ColumnValues::F32(Vec::new());
        append_event(&sdesc, &sraw, 3, 1, &mut soff, &mut svals).unwrap();
        assert_eq!(svals, ColumnValues::F32(vec![20.0]));
    }

    #[test]
    fn append_event_bounds_checked() {
        let desc = BranchDesc::scalar("s", DType::F32);
        let mut off = Vec::new();
        let mut vals = ColumnValues::F32(Vec::new());
        assert!(append_event(&desc, &[0u8; 4], 1, 1, &mut off, &mut vals).is_err());
        let jd = BranchDesc::jagged("j", DType::F32, "J");
        assert!(append_event(&jd, &[0u8; 3], 1, 0, &mut off, &mut vals).is_err());
    }

    #[test]
    fn empty_basket() {
        let col = ColumnData::scalar_f32(vec![]);
        let desc = BranchDesc::scalar("x", DType::F32);
        let raw = encode(&col, 0, 0);
        let dec = decode(&desc, &raw, 0, 0).unwrap();
        assert_eq!(dec.values.len(), 0);
    }
}
