//! # SkimROOT — near-storage LHC data filtering
//!
//! Reproduction of *"SkimROOT: Accelerating LHC Data Filtering with
//! Near-Storage Processing"* (cs.DC 2025) as a three-layer
//! Rust + JAX + Pallas system, organized around three open APIs (see
//! `ARCHITECTURE.md` for the full design):
//!
//! ## The query IR (Layer 0)
//!
//! What a skim *selects* is an open typed expression AST
//! ([`query::expr::Expr`]): branch refs, arithmetic, boolean
//! structure, jagged-collection aggregations. Frontends — the fluent
//! Rust builder on [`SkimQuery`], the TCut-style cut-string parser
//! ([`query::parse`]), and the legacy Figure-2c JSON schema (now
//! sugar) — all lower to it; the planner ([`query::plan`]) classifies
//! IR conjuncts onto the AOT kernel's fixed-function stages where they
//! fit and compiles the rest for the interpreter, keeping
//! `fits_kernel()` the honest vectorization gate.
//!
//! ## The execution API, in two layers
//!
//! * **Stage pipeline** ([`engine::pipeline`]) — the skim itself is a
//!   sequence of pluggable [`FilterStage`]s with netfilter-style
//!   [`Verdict`] semantics (`Continue` / `Drop`), registered by name
//!   with `after` ordering at two hooks: per cluster **group**
//!   (`fetch → decompress → deserialize → eval`) and per **job**
//!   (`phase2 → output`). Custom stages — byte accounting, sampling,
//!   extra vetoes — slot in without forking the engine.
//! * **Open topology** ([`coordinator`]) — *where* filtering runs is a
//!   [`Deployment`] built from [`Placement`] (`Client`, `Server`, or
//!   `Dpu(DpuConfig)`), link/disk models, execution policy, and an
//!   optional multi-DPU `fan_out`. The paper's four methods
//!   ([`Mode`]) are thin presets over the same builder, so the
//!   Figure 4/5 comparison rows are ordinary deployments.
//!
//! [`SkimJob`] is the top-level facade tying both together; the CLI
//! (`main.rs`), the DPU HTTP service ([`dpu::http`]), the eval harness
//! ([`coordinator::eval`]) and the `examples/` all go through it.
//!
//! ## The dataset layer
//!
//! The unit of work is a **dataset**, not a file: a query's input is
//! a [`DatasetSpec`] — one file (the legacy contract, unchanged), an
//! explicit list, a glob over the storage export, or a named catalog
//! — resolved and traversal-validated by [`catalog`]. Multi-file jobs
//! run per file with fault isolation and per-file retries, stripe
//! whole files across DPU fan-out lanes, and merge deterministically
//! through [`troot::merge`] (byte-stable regardless of fan-out,
//! parallelism and completion order).
//!
//! Data files can carry **zone-map sidecars** ([`index`], `.tridx`):
//! per-basket min/max summaries that the planner compiles conjuncts
//! against so the engine skips provably-dead baskets before any I/O —
//! with staleness detection so a mismatched sidecar degrades to a full
//! scan, never a wrong answer. Skim outputs can be registered back
//! into the catalog as **materialized skims** carrying lineage,
//! re-skimmable via `catalog:NAME` like any dataset.
//!
//! ## The three layers
//!
//! * **Layer 3 (this crate)** — a ROOT-like columnar storage substrate
//!   ([`troot`]), compression codecs ([`compress`]), an XRootD-like
//!   remote-access protocol with TTreeCache prefetching ([`xrootd`]),
//!   a simulated network fabric ([`net`]), the JSON query front-end
//!   ([`query`]), the two-phase multi-stage filtering engine
//!   ([`engine`]), the DPU near-storage node and cluster models
//!   ([`dpu`]), and the job coordinator ([`coordinator`]).
//! * **Layer 2** — `python/compile/model.py`: the JAX selection graph
//!   (preselection → object-level → event-level) lowered once to HLO
//!   text by `python/compile/aot.py`.
//! * **Layer 1** — `python/compile/kernels/skim.py`: the Pallas
//!   cut-evaluation kernel that the JAX graph calls.
//!
//! ## The serving layer
//!
//! Beyond one-shot jobs, [`serve`] turns the system into a long-lived
//! **multi-tenant skim service**: a bounded-worker-pool job scheduler
//! with admission control ([`serve::SkimScheduler`]) and a shared
//! server-side decompressed-basket cache ([`serve::BasketCache`],
//! LRU by bytes, single-flight) that every concurrent job's engine
//! consults before fetching + decompressing — so many queries over one
//! hot dataset share scans instead of repeating them. The wire
//! protocol grows `SubmitQuery` / `JobStatus` / `FetchResult` frames,
//! the DPU HTTP endpoint grows `POST /jobs` routes, and the CLI
//! front-end is `skimroot serve`.
//!
//! On top of the cache sits the **shared-scan batch executor**
//! ([`mqo`] + [`engine::run_shared`]): jobs submitted within a short
//! batching window (`skimroot serve --batch-window-ms`) that target
//! the same resolved dataset are merged into one batch whose single
//! fetch → decompress → deserialize pass over the *union* of the
//! members' criteria branches serves every member — per-member masks,
//! funnels and output files stay byte-identical to solo runs, and
//! scan costs are charged once to the batch then amortized across
//! members as exact integer counter shares and `1/N` virtual-time
//! slices.
//!
//! Python never runs on the request path: the Rust binary loads the
//! AOT artifacts through [`runtime`] (PJRT CPU client via the `xla`
//! crate, behind the `pjrt` cargo feature; the default build uses the
//! bit-identical scalar interpreter).

#![warn(missing_docs)]

pub mod catalog;
pub mod cli;
pub mod compress;
pub mod coordinator;
pub mod dpu;
pub mod engine;
pub mod gen;
pub mod index;
pub mod job;
pub mod lifecycle;
pub mod metrics;
pub mod mqo;
pub mod net;
pub mod query;
pub mod runtime;
pub mod serve;
pub mod troot;
pub mod util;
pub mod xrootd;

pub use coordinator::{Deployment, JobReport, Mode, Placement};
pub use engine::{FilterStage, Hook, StageCtx, Verdict};
pub use job::SkimJob;
pub use lifecycle::{CancelToken, FaultKind, FaultPlan, JobCtl};
pub use query::{DatasetSpec, Expr, SkimQuery};
pub use serve::{BasketCache, SkimScheduler, SkimService};

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Underlying I/O failure (file system, sockets).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    /// Malformed troot file or metadata.
    #[error("format error: {0}")]
    Format(String),
    /// Codec failure (bad frame, checksum mismatch, unknown codec).
    #[error("compression error: {0}")]
    Compress(String),
    /// Wire-protocol violation (framing, opcodes, HTTP parsing).
    #[error("protocol error: {0}")]
    Protocol(String),
    /// Invalid query (JSON schema, cut-string syntax, planning).
    #[error("query error: {0}")]
    Query(String),
    /// Filtering-engine failure.
    #[error("engine error: {0}")]
    Engine(String),
    /// PJRT runtime unavailable or kernel evaluation failure.
    #[error("runtime error: {0}")]
    Runtime(String),
    /// Invalid configuration (CLI flags, deployments, admission
    /// control rejections).
    #[error("config error: {0}")]
    Config(String),
    /// The job was cooperatively cancelled ([`lifecycle::CancelToken`]).
    /// Terminal: retry loops never resubmit a cancelled job.
    #[error("cancelled: {0}")]
    Cancelled(String),
    /// The job's virtual-time deadline passed ([`lifecycle::JobCtl`]).
    /// Terminal: retry loops never resubmit past the deadline.
    #[error("deadline exceeded: {0}")]
    DeadlineExceeded(String),
}

impl Error {
    /// Shorthand for [`Error::Format`].
    pub fn format(msg: impl Into<String>) -> Self {
        Error::Format(msg.into())
    }
    /// Shorthand for [`Error::Protocol`].
    pub fn protocol(msg: impl Into<String>) -> Self {
        Error::Protocol(msg.into())
    }
    /// Shorthand for [`Error::Query`].
    pub fn query(msg: impl Into<String>) -> Self {
        Error::Query(msg.into())
    }
}
