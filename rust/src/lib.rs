//! # SkimROOT — near-storage LHC data filtering
//!
//! Reproduction of *"SkimROOT: Accelerating LHC Data Filtering with
//! Near-Storage Processing"* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: a ROOT-like columnar
//!   storage substrate ([`troot`]), compression codecs ([`compress`]),
//!   an XRootD-like remote-access protocol with TTreeCache prefetching
//!   ([`xrootd`]), a simulated network fabric ([`net`]), the JSON query
//!   front-end ([`query`]), the two-phase multi-stage filtering engine
//!   ([`engine`]), the DPU near-storage node model ([`dpu`]), and the
//!   job coordinator ([`coordinator`]).
//! * **Layer 2** — `python/compile/model.py`: the JAX selection graph
//!   (preselection → object-level → event-level) lowered once to HLO
//!   text by `python/compile/aot.py`.
//! * **Layer 1** — `python/compile/kernels/skim.py`: the Pallas
//!   cut-evaluation kernel that the JAX graph calls.
//!
//! Python never runs on the request path: the Rust binary loads the AOT
//! artifacts through [`runtime`] (PJRT CPU client via the `xla` crate).

pub mod cli;
pub mod compress;
pub mod coordinator;
pub mod dpu;
pub mod engine;
pub mod gen;
pub mod metrics;
pub mod net;
pub mod query;
pub mod runtime;
pub mod troot;
pub mod util;
pub mod xrootd;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("format error: {0}")]
    Format(String),
    #[error("compression error: {0}")]
    Compress(String),
    #[error("protocol error: {0}")]
    Protocol(String),
    #[error("query error: {0}")]
    Query(String),
    #[error("engine error: {0}")]
    Engine(String),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("config error: {0}")]
    Config(String),
}

impl Error {
    pub fn format(msg: impl Into<String>) -> Self {
        Error::Format(msg.into())
    }
    pub fn protocol(msg: impl Into<String>) -> Self {
        Error::Protocol(msg.into())
    }
    pub fn query(msg: impl Into<String>) -> Self {
        Error::Query(msg.into())
    }
}
