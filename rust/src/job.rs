//! [`SkimJob`] — the top-level facade: one fluent entry point that the
//! CLI, the DPU HTTP service, the eval harness and the examples all
//! share.
//!
//! A job is a query plus a [`Deployment`] (where filtering runs, over
//! which links) plus the local context (storage root, client output
//! directory, optional PJRT runtime) plus any custom pipeline stages.
//! The query's input is a dataset spec ([`crate::query::DatasetSpec`]):
//! one file keeps the legacy single-file contract, while a glob
//! (`"store/*.troot"`), an explicit list or a `catalog:NAME` reference
//! runs the whole dataset — per-file fault isolation, DPU striping and
//! a deterministic merged output (see [`crate::catalog`] and the
//! coordinator's dataset path):
//!
//! ```no_run
//! use skimroot::net::LinkModel;
//! use skimroot::{Deployment, SkimJob, SkimQuery};
//!
//! let query = SkimQuery::new("events.troot", "skim.troot")
//!     .keep(&["Muon_*", "MET_pt"])
//!     .with_cut_str("nMuon >= 2 && max(Muon_pt) > 30")?;
//! let report = SkimJob::new(query)
//!     .storage("eval_data/storage")
//!     .client_dir("eval_data/client")
//!     .deployment(Deployment::skim_root(LinkModel::wan_1g()))
//!     .run()?;
//! println!("pass {}/{}", report.result.n_pass, report.result.n_events);
//! # Ok::<(), skimroot::Error>(())
//! ```

use crate::coordinator::{Coordinator, Deployment, JobReport};
use crate::engine::{FilterStage, Hook, StageReg};
use crate::lifecycle::JobCtl;
use crate::net::LinkModel;
use crate::query::SkimQuery;
use crate::runtime::SkimRuntime;
use crate::Result;
use std::path::PathBuf;
use std::sync::Arc;

/// A configured skim job, ready to run. See the module docs.
pub struct SkimJob<'rt> {
    query: SkimQuery,
    deployment: Deployment,
    storage_root: PathBuf,
    client_dir: PathBuf,
    runtime: Option<&'rt SkimRuntime>,
    stages: Vec<StageReg>,
    basket_cache: Option<Arc<crate::serve::BasketCache>>,
    materialize_as: Option<String>,
    ctl: JobCtl,
}

impl<'rt> SkimJob<'rt> {
    /// A job for `query` with defaults: the SkimROOT (DPU) preset over
    /// a 1 Gbps WAN, storage in the current directory, outputs under
    /// `skim_client/`, interpreter evaluation (no runtime).
    pub fn new(query: SkimQuery) -> Self {
        SkimJob {
            query,
            deployment: Deployment::skim_root(LinkModel::wan_1g()),
            storage_root: PathBuf::from("."),
            client_dir: PathBuf::from("skim_client"),
            runtime: None,
            stages: Vec::new(),
            basket_cache: None,
            materialize_as: None,
            ctl: JobCtl::none(),
        }
    }

    /// Directory the storage server exports (holds the input file).
    pub fn storage(mut self, root: impl Into<PathBuf>) -> Self {
        self.storage_root = root.into();
        self
    }

    /// Directory where the filtered output lands at the client.
    pub fn client_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.client_dir = dir.into();
        self
    }

    /// The topology to run under (preset or builder-made).
    pub fn deployment(mut self, deployment: Deployment) -> Self {
        self.deployment = deployment;
        self
    }

    /// PJRT runtime for vectorized evaluation (`None` = interpreter).
    pub fn runtime(mut self, runtime: Option<&'rt SkimRuntime>) -> Self {
        self.runtime = runtime;
        self
    }

    /// Register a custom pipeline stage; it is installed into every
    /// engine the deployment spins up (all shards of a fan-out).
    pub fn stage(mut self, hook: Hook, after: &[&str], stage: Arc<dyn FilterStage>) -> Self {
        self.stages.push(StageReg::new(hook, after, stage));
        self
    }

    /// Share a server-side decompressed-basket cache with other jobs:
    /// every engine this job spins up consults `cache` before its
    /// fetch/decompress stages. The multi-tenant serving layer
    /// ([`crate::serve`]) installs one cache into every job it runs.
    pub fn basket_cache(mut self, cache: Arc<crate::serve::BasketCache>) -> Self {
        self.basket_cache = Some(cache);
        self
    }

    /// Virtual-time deadline in milliseconds (`0` = none). The job is
    /// aborted with [`crate::Error::DeadlineExceeded`] once its
    /// timeline's elapsed virtual time — real compute plus modeled
    /// transport, stalls and retry backoff — passes the deadline.
    /// Checked cooperatively at basket-group boundaries, so the job
    /// stops within one group of the deadline. Also installs a fresh
    /// cancel token, retrievable via [`SkimJob::cancel_token`].
    pub fn deadline_ms(mut self, deadline_ms: u64) -> Self {
        self.ctl = JobCtl::with_deadline_ms(deadline_ms);
        self
    }

    /// Use an externally-created control block (shared cancel token
    /// and/or deadline). The serving layer uses this to wire one token
    /// per scheduler job through to the engines.
    pub fn ctl(mut self, ctl: JobCtl) -> Self {
        self.ctl = ctl;
        self
    }

    /// The cancel token this job will honor, if any: call
    /// [`crate::lifecycle::CancelToken::cancel`] from another thread to
    /// stop the job at the next basket-group boundary with
    /// [`crate::Error::Cancelled`].
    pub fn cancel_token(&self) -> Option<crate::lifecycle::CancelToken> {
        self.ctl.cancel.clone()
    }

    /// Register the finished skim output back into the storage root's
    /// catalog as `catalog:<name>` (a **materialized skim**): the
    /// output is copied under `skims/`, a `.tridx` zone-map sidecar is
    /// derived for it, and `<name>.catalog` records its lineage
    /// (source dataset + canonical cut). Later queries can use
    /// `catalog:<name>` as an ordinary input (CLI:
    /// `skim --materialize NAME`).
    pub fn materialize(mut self, name: impl Into<String>) -> Self {
        self.materialize_as = Some(name.into());
        self
    }

    /// The query this job will run.
    pub fn query(&self) -> &SkimQuery {
        &self.query
    }

    /// The topology this job will run under.
    pub fn deployment_ref(&self) -> &Deployment {
        &self.deployment
    }

    /// Build and render the execution plan — the selection expression
    /// tree, phase-1/phase-2 branch fetch sets and the kernel-fit
    /// decision — without running the job (CLI `skim --explain`).
    /// Reads only file metadata from the storage root. For a dataset
    /// query the resolved file list is rendered first and the plan is
    /// built against the first file's schema (per-file fetch sets are
    /// identical across a homogeneous dataset).
    pub fn explain(&self) -> Result<String> {
        let files = crate::catalog::resolve(&self.query.input, &self.storage_root)?;
        let store = crate::troot::LocalFile::open(self.storage_root.join(&files[0]))?;
        let reader = crate::troot::TRootReader::open(store)?;
        let plan = crate::query::plan::SkimPlan::build(&self.query, reader.meta())?;
        let mut out = String::new();
        if !self.query.input.is_single() {
            out.push_str(&format!(
                "dataset: {} files resolved from '{}'\n",
                files.len(),
                self.query.input
            ));
            for f in &files {
                out.push_str(&format!("  {f}\n"));
            }
        }
        out.push_str(&plan.explain(&self.query));
        Ok(out)
    }

    /// Render the **adaptive conjunct inventory** for this query (CLI
    /// `skim --explain --stats`): one line per funnel conjunct with its
    /// fixed stage, structural cost estimate and canonical key. When
    /// the input is a `catalog:NAME` materialized skim with a
    /// persisted `skims/NAME.prof` selectivity sidecar, the measured
    /// visited/passed tallies and pass rates from that profile are
    /// printed alongside — exactly the numbers an adaptive run would
    /// warm-start from.
    pub fn explain_stats(&self) -> Result<String> {
        use std::fmt::Write as _;
        let files = crate::catalog::resolve(&self.query.input, &self.storage_root)?;
        let store = crate::troot::LocalFile::open(self.storage_root.join(&files[0]))?;
        let reader = crate::troot::TRootReader::open(store)?;
        let plan = crate::query::plan::SkimPlan::build(&self.query, reader.meta())?;
        let conjuncts = crate::query::stats::conjuncts_of(&plan.program);
        let mut out = String::new();
        if conjuncts.is_empty() {
            out.push_str("conjunct inventory: (no cut — every event passes)\n");
            return Ok(out);
        }
        let profile = match &self.query.input {
            crate::query::DatasetSpec::Catalog(name) => {
                let path = self.storage_root.join("skims").join(format!("{name}.prof"));
                std::fs::read_to_string(&path)
                    .ok()
                    .map(|t| crate::query::SelectivityProfile::from_text(&t))
                    .filter(|p| !p.is_empty())
            }
            _ => None,
        };
        let _ = writeln!(out, "conjunct inventory ({} conjuncts):", conjuncts.len());
        let _ = writeln!(
            out,
            "  {:>5} {:>8} {:>10} {:>10} {:>7}  conjunct",
            "stage", "cost", "visited", "passed", "pass%"
        );
        for c in &conjuncts {
            match profile.as_ref().and_then(|p| p.get(&c.key)) {
                Some(s) => {
                    let _ = writeln!(
                        out,
                        "  {:>5} {:>8.1} {:>10} {:>10} {:>6.1}%  {}",
                        c.stage,
                        c.cost,
                        s.visited,
                        s.passed,
                        100.0 * s.pass_rate(),
                        c.key
                    );
                }
                None => {
                    let _ = writeln!(
                        out,
                        "  {:>5} {:>8.1} {:>10} {:>10} {:>7}  {}",
                        c.stage, c.cost, "-", "-", "-", c.key
                    );
                }
            }
        }
        match profile {
            Some(_) => out.push_str(
                "  (measured tallies from the persisted selectivity profile; an\n   \
                 adaptive run over this skim warm-starts from them)\n",
            ),
            None => out.push_str(
                "  (no persisted profile — an adaptive run starts with a warm-up\n   \
                 window in the fixed stage order above)\n",
            ),
        }
        Ok(out)
    }

    /// Render the **kernel fusion plan** for this query (CLI
    /// `skim --explain --fuse`): one line per funnel conjunct, in
    /// evaluation order, saying which fused kernel it compiled into
    /// (`cmp` / `range` / `and-chain` / `count` / `sum`) — or why it
    /// stays on the interpreter. The plan is built exactly like a
    /// fuse-only run's: identity conjunct order and, when the input is
    /// a `catalog:NAME` materialized skim with a persisted
    /// `skims/NAME.prof` sidecar, the measured tallies gating all-pass
    /// conjuncts out of fusion. Nothing is executed.
    pub fn explain_fuse(&self) -> Result<String> {
        let files = crate::catalog::resolve(&self.query.input, &self.storage_root)?;
        let store = crate::troot::LocalFile::open(self.storage_root.join(&files[0]))?;
        let reader = crate::troot::TRootReader::open(store)?;
        let plan = crate::query::plan::SkimPlan::build(&self.query, reader.meta())?;
        let conjuncts = crate::query::stats::conjuncts_of(&plan.program);
        if conjuncts.is_empty() {
            return Ok("fusion plan: (no cut — nothing to fuse)\n".to_string());
        }
        let mut stats = vec![crate::query::ConjunctStats::default(); conjuncts.len()];
        let mut seeded = false;
        if let crate::query::DatasetSpec::Catalog(name) = &self.query.input {
            let path = self.storage_root.join("skims").join(format!("{name}.prof"));
            if let Ok(text) = std::fs::read_to_string(&path) {
                let profile = crate::query::SelectivityProfile::from_text(&text);
                for (c, st) in conjuncts.iter().zip(stats.iter_mut()) {
                    if let Some(prev) = profile.get(&c.key) {
                        *st = *prev;
                        seeded = true;
                    }
                }
            }
        }
        let order: Vec<usize> = (0..conjuncts.len()).collect();
        let fuse = crate::query::fuse::fuse_plan(&plan.program, &conjuncts, &order, &stats);
        let mut out = fuse.describe();
        out.push_str(if seeded {
            "  (all-pass gating uses the persisted selectivity profile; under\n   \
             --adaptive the plan is rebuilt as the order re-ranks)\n"
        } else {
            "  (no persisted profile — unmeasured conjuncts fuse on the 0.5\n   \
             prior; under --adaptive the plan is rebuilt as the order re-ranks)\n"
        });
        Ok(out)
    }

    /// Execute the job (with the deployment's WLCG-style retries),
    /// then register the output as a materialized skim if
    /// [`SkimJob::materialize`] was requested.
    ///
    /// Adaptive warm start: when [`Deployment::adaptive`] is enabled,
    /// the input is a `catalog:NAME` materialized skim, and no seed
    /// profile was supplied, the `skims/NAME.prof` sidecar (persisted
    /// by a previous materializing run) seeds the conjunct order from
    /// the first group. A materializing adaptive run writes that
    /// sidecar next to the skim.
    pub fn run(&self) -> Result<JobReport> {
        let mut coord = Coordinator::new(&self.storage_root, &self.client_dir, self.runtime);
        if let Some(cache) = &self.basket_cache {
            coord = coord.with_basket_cache(cache.clone());
        }
        if self.ctl.is_active() {
            coord = coord.with_ctl(self.ctl.clone());
        }
        let mut deployment = self.deployment.clone();
        if deployment.adaptive.enabled && deployment.adaptive.seed.is_none() {
            if let crate::query::DatasetSpec::Catalog(name) = &self.query.input {
                let path = self.storage_root.join("skims").join(format!("{name}.prof"));
                if let Ok(text) = std::fs::read_to_string(&path) {
                    let seed = crate::query::SelectivityProfile::from_text(&text);
                    if !seed.is_empty() {
                        deployment.adaptive.seed = Some(seed);
                    }
                }
            }
        }
        let report = coord.run_job_with(&self.query, &deployment, &self.stages)?;
        if let Some(name) = &self.materialize_as {
            crate::catalog::register_materialized(
                &self.storage_root,
                name,
                &report.result.output_path,
                &self.query.input,
                self.query.combined_cut().as_ref(),
            )?;
            // Persist the selectivity profile beside the skim so a
            // later `catalog:{name}` query starts warm.
            let prof = report.timeline.profile();
            if !prof.is_empty() {
                let mut sp = crate::query::SelectivityProfile::default();
                for p in &prof {
                    sp.record(&p.key, p.visited, p.passed, p.cost_us);
                }
                let path = self.storage_root.join("skims").join(format!("{name}.prof"));
                std::fs::write(&path, sp.to_text()).map_err(crate::Error::Io)?;
            }
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::coordinator::Placement;
    use crate::engine::{StageCtx, Verdict};
    use crate::gen::{self, GenConfig};

    fn setup(tag: &str) -> (PathBuf, PathBuf) {
        let dir = std::env::temp_dir().join(format!("job_{}_{tag}", std::process::id()));
        let storage = dir.join("storage");
        let client = dir.join("client");
        std::fs::create_dir_all(&storage).unwrap();
        let path = storage.join("events.troot");
        if !path.exists() {
            let cfg = GenConfig {
                n_events: 700,
                target_branches: 170,
                n_hlt: 40,
                basket_events: 200,
                codec: Codec::Lz4,
                seed: 5,
            };
            gen::generate(&cfg, &path).unwrap();
        }
        (storage, client)
    }

    #[test]
    fn facade_runs_preset_deployment() {
        let (storage, client) = setup("preset");
        let report = SkimJob::new(gen::higgs_query("events.troot", "out.troot"))
            .storage(&storage)
            .client_dir(&client)
            .run()
            .unwrap();
        assert_eq!(report.name, "skimroot");
        assert!(report.result.n_pass > 0);
        assert!(client.join("out.troot").exists());
    }

    /// Counts groups seen — exercises custom stages through the facade.
    struct GroupCounter {
        seen: std::sync::atomic::AtomicU64,
    }
    impl FilterStage for GroupCounter {
        fn name(&self) -> &str {
            "group-counter"
        }
        fn run(&self, ctx: &mut StageCtx) -> Result<Verdict> {
            if ctx.group.is_some() {
                self.seen.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
            Ok(Verdict::Continue)
        }
    }

    #[test]
    fn facade_explains_without_running() {
        let (storage, client) = setup("explain");
        let query = gen::higgs_query("events.troot", "unused.troot")
            .with_cut_str("MET_pt > 25 || max(Jet_pt) > 60")
            .unwrap();
        let job = SkimJob::new(query).storage(&storage).client_dir(&client);
        let text = job.explain().unwrap();
        assert!(text.contains("selection expression:"));
        assert!(text.contains("kernel fallback"), "{text}");
        assert!(text.contains("residual IR expression"), "{text}");
        // Explain must not execute the job.
        assert!(!client.join("unused.troot").exists());
    }

    #[test]
    fn facade_runs_cut_string_query_on_interpreter() {
        let (storage, client) = setup("cutstr");
        // `||` across a trigger and a kinematic aggregation — not
        // expressible in the legacy structured schema.
        let query = SkimQuery::new("events.troot", "cutstr.troot")
            .keep(&["Muon_pt", "nMuon", "MET_pt"])
            .with_cut_str("nMuon >= 1 && (HLT_IsoMu24 || max(Muon_pt) > 30)")
            .unwrap();
        let report = SkimJob::new(query)
            .storage(&storage)
            .client_dir(&client)
            .run()
            .unwrap();
        assert!(!report.result.vectorized);
        assert!(report.result.n_pass > 0);
        assert!(report.result.n_pass < report.result.n_events);
        assert!(client.join("cutstr.troot").exists());
    }

    #[test]
    fn materialized_skim_is_reskimmable_via_catalog_name() {
        let (storage, client) = setup("materialize");
        // Skim once, registering the output as `catalog:met_skim`.
        let first = SkimJob::new(
            SkimQuery::new("events.troot", "met_pass.troot")
                .keep(&["MET_pt", "nJet", "Jet_pt", "event"])
                .with_cut_str("MET_pt > 30")
                .unwrap(),
        )
        .storage(&storage)
        .client_dir(&client)
        .materialize("met_skim")
        .run()
        .unwrap();
        assert!(first.result.n_pass > 0);
        assert!(storage.join("skims/met_skim.troot").is_file());
        assert!(storage.join("skims/met_skim.troot.tridx").is_file());

        // The lineage records where the skim came from.
        let lin = crate::catalog::read_lineage(&storage, "met_skim")
            .unwrap()
            .expect("materialized entry");
        assert_eq!(lin.source, "events.troot");
        assert!(lin.cut.contains("MET_pt"), "{}", lin.cut);

        // The materialized entry is an ordinary input: skim the skim.
        let second = SkimJob::new(
            SkimQuery::new("catalog:met_skim", "met_tight.troot")
                .keep(&["MET_pt", "nJet"])
                .with_cut_str("MET_pt > 60")
                .unwrap(),
        )
        .storage(&storage)
        .client_dir(&client)
        .run()
        .unwrap();
        assert_eq!(second.result.n_events, first.result.n_pass);
        assert!(second.result.n_pass < second.result.n_events);
        assert!(client.join("met_tight.troot").exists());
    }

    #[test]
    fn adaptive_profile_persists_and_warm_starts_catalog_queries() {
        let (storage, client) = setup("adprof");
        let adaptive = crate::engine::AdaptiveOpts {
            enabled: true,
            warmup_groups: 1,
            replan_every: 1,
            seed: None,
        };
        let dep = Deployment::builder()
            .placement(Placement::Client)
            .use_pjrt(false)
            .adaptive(adaptive)
            .build()
            .unwrap();
        let first = SkimJob::new(
            SkimQuery::new("events.troot", "ad_pass.troot")
                .keep(&["MET_pt", "nJet", "Jet_pt", "event"])
                .with_cut_str("MET_pt > 30 && nJet >= 1")
                .unwrap(),
        )
        .storage(&storage)
        .client_dir(&client)
        .deployment(dep.clone())
        .materialize("ad_skim")
        .run()
        .unwrap();
        assert!(first.result.n_pass > 0);
        let prof_path = storage.join("skims/ad_skim.prof");
        assert!(prof_path.is_file(), "materializing adaptive run writes the sidecar");
        let seed = crate::query::SelectivityProfile::from_text(
            &std::fs::read_to_string(&prof_path).unwrap(),
        );
        assert!(seed.get("MET_pt > 30").is_some(), "{seed:?}");

        // Re-skim via the catalog name: the warm-started order must not
        // change results vs a cold adaptive run of the same query.
        let requery = |out: &str, dep: Deployment| {
            SkimJob::new(
                SkimQuery::new("catalog:ad_skim", out)
                    .keep(&["MET_pt", "nJet"])
                    .with_cut_str("MET_pt > 60")
                    .unwrap(),
            )
            .storage(&storage)
            .client_dir(&client)
            .deployment(dep)
            .run()
            .unwrap()
        };
        let warm = requery("ad_warm.troot", dep);
        let cold_dep = Deployment::builder()
            .placement(Placement::Client)
            .use_pjrt(false)
            .build()
            .unwrap();
        let cold = requery("ad_cold.troot", cold_dep);
        assert_eq!(warm.result.n_pass, cold.result.n_pass);
        let a = std::fs::read(client.join("ad_warm.troot")).unwrap();
        let b = std::fs::read(client.join("ad_cold.troot")).unwrap();
        assert_eq!(a, b, "warm start must not change the output bytes");

        // `--explain --stats` over the materialized skim renders the
        // conjunct inventory with the persisted measured tallies.
        let stats = SkimJob::new(
            SkimQuery::new("catalog:ad_skim", "unused.troot")
                .keep(&["MET_pt"])
                .with_cut_str("MET_pt > 30 && nJet >= 1")
                .unwrap(),
        )
        .storage(&storage)
        .explain_stats()
        .unwrap();
        assert!(stats.contains("conjunct inventory"), "{stats}");
        assert!(stats.contains("MET_pt > 30"), "{stats}");
        assert!(stats.contains("persisted selectivity profile"), "{stats}");
    }

    #[test]
    fn facade_threads_custom_stages_into_deployments() {
        let (storage, client) = setup("stages");
        let counter = Arc::new(GroupCounter { seen: std::sync::atomic::AtomicU64::new(0) });
        let dep = Deployment::builder()
            .name("counted")
            .placement(Placement::Client)
            .link(LinkModel::dedicated_100g())
            .use_pjrt(false)
            .build()
            .unwrap();
        let report = SkimJob::new(gen::higgs_query("events.troot", "counted.troot"))
            .storage(&storage)
            .client_dir(&client)
            .deployment(dep)
            .stage(Hook::Group, &["eval"], counter.clone())
            .run()
            .unwrap();
        assert!(report.result.n_pass > 0);
        assert!(counter.seen.load(std::sync::atomic::Ordering::Relaxed) > 0);
    }
}
