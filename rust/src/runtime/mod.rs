//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and evaluates the vectorized cut kernel on
//! the request path — **no Python anywhere here**.
//!
//! `make artifacts` runs once at build time; this module reads
//! `artifacts/manifest.json` (shapes, argument order, capacities),
//! parses each `skim_<variant>.hlo.txt` with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU
//! client, and exposes [`SkimRuntime::eval`].
//!
//! The PJRT/XLA backend is gated behind the **`pjrt` cargo feature**
//! (it needs the `xla` crate, unavailable offline). Without the
//! feature, [`SkimRuntime::load`] returns an error and every caller
//! falls back to the scalar interpreter ([`crate::engine::interp`]),
//! which produces bit-identical masks. The batch/parameter types below
//! are shared by both paths and always compiled.
//!
//! Argument order (fixed by the manifest, keep in sync with `aot.py`):
//! `cols[C,B,M], nobj[C,B], scalars[S,B], obj_cuts[K,5], groups[G,4],
//! scalar_cuts[K2,5], ht[4], trig[1+S]` → tuple
//! `(mask[B], stages[4,B], stage_counts[4], cum_counts[4], n_pass[1])`.

use crate::query::plan::CutProgram;
use crate::{Error, Result};

/// Kernel capacities, read from the manifest (must agree with
/// `crate::query::plan` constants for programs to pack).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Capacities {
    /// Jagged (object) columns.
    pub c: usize,
    /// Scalar columns.
    pub s: usize,
    /// Per-object cut slots.
    pub k_obj: usize,
    /// Scalar cut slots.
    pub k_sc: usize,
    /// Object-group slots.
    pub g: usize,
    /// Funnel stages (4: pre, object, HT, trigger).
    pub n_stages: usize,
}

/// Packed cut-program parameter bank (f32 rows as the kernel expects).
#[derive(Debug, Clone, PartialEq)]
pub struct CutParams {
    /// Object-cut bank, `[K_OBJ * 5]`.
    pub obj_cuts: Vec<f32>,
    /// Object-group bank, `[G * 4]`.
    pub groups: Vec<f32>,
    /// Scalar-cut bank, `[K_SC * 5]`.
    pub scalar_cuts: Vec<f32>,
    /// HT unit parameters, `[4]`.
    pub ht: Vec<f32>,
    /// Trigger mask, `[1 + S]` (leading enable flag).
    pub trig: Vec<f32>,
}

impl CutParams {
    /// Pack a compiled [`CutProgram`] into the kernel's parameter bank.
    pub fn pack(program: &CutProgram, caps: &Capacities) -> Result<CutParams> {
        if !program.fits_kernel() {
            return Err(Error::Runtime(
                "cut program exceeds kernel capacity (use the interpreter)".into(),
            ));
        }
        let mut obj_cuts = vec![0.0f32; caps.k_obj * 5];
        for (k, cut) in program.obj_cuts.iter().enumerate() {
            obj_cuts[k * 5] = 1.0;
            obj_cuts[k * 5 + 1] = cut.col as f32;
            obj_cuts[k * 5 + 2] = cut.op as f32;
            obj_cuts[k * 5 + 3] = cut.abs as u8 as f32;
            obj_cuts[k * 5 + 4] = cut.value;
        }
        let mut groups = vec![0.0f32; caps.g * 4];
        for (g, grp) in program.groups.iter().enumerate() {
            groups[g * 4] = 1.0;
            groups[g * 4 + 1] = grp.cut_range.start as f32;
            groups[g * 4 + 2] = grp.cut_range.end as f32;
            groups[g * 4 + 3] = grp.min_count as f32;
        }
        let mut scalar_cuts = vec![0.0f32; caps.k_sc * 5];
        for (k, cut) in program.scalar_cuts.iter().enumerate() {
            scalar_cuts[k * 5] = 1.0;
            scalar_cuts[k * 5 + 1] = cut.col as f32;
            scalar_cuts[k * 5 + 2] = cut.op as f32;
            scalar_cuts[k * 5 + 3] = cut.abs as u8 as f32;
            scalar_cuts[k * 5 + 4] = cut.value;
        }
        let mut ht = vec![0.0f32; 4];
        if let Some(h) = &program.ht {
            ht[0] = 1.0;
            ht[1] = h.col as f32;
            ht[2] = h.object_pt_min;
            ht[3] = h.min_ht;
        }
        let mut trig = vec![0.0f32; 1 + caps.s];
        if !program.triggers.is_empty() {
            trig[0] = 1.0;
            for &t in &program.triggers {
                trig[1 + t] = 1.0;
            }
        }
        Ok(CutParams { obj_cuts, groups, scalar_cuts, ht, trig })
    }
}

/// A padded input batch (row-major flattened).
#[derive(Debug, Clone)]
pub struct Batch {
    /// `[C * B * M]`, C-major.
    pub cols: Vec<f32>,
    /// `[C * B]`.
    pub nobj: Vec<f32>,
    /// `[S * B]`.
    pub scalars: Vec<f32>,
    /// Events actually populated (≤ B); the rest is padding.
    pub n_valid: usize,
    /// Batch capacity in events.
    pub b: usize,
    /// Object-slot capacity per event.
    pub m: usize,
}

impl Batch {
    /// A zero-filled batch for the given capacities and shape.
    pub fn zeroed(caps: &Capacities, b: usize, m: usize) -> Batch {
        Batch {
            cols: vec![0.0; caps.c * b * m],
            nobj: vec![0.0; caps.c * b],
            scalars: vec![0.0; caps.s * b],
            n_valid: 0,
            b,
            m,
        }
    }

    /// Zero every value and forget validity, keeping the allocations —
    /// the engine reuses one batch across flush windows and cluster
    /// groups instead of re-allocating `zeroed` arrays per window.
    pub fn reset(&mut self) {
        self.cols.fill(0.0);
        self.nobj.fill(0.0);
        self.scalars.fill(0.0);
        self.n_valid = 0;
    }
}

/// Kernel outputs for one batch (padding trimmed to `n_valid`).
#[derive(Debug, Clone)]
pub struct MaskResult {
    /// 0.0/1.0 final decision per event.
    pub mask: Vec<f32>,
    /// `[4][n_valid]` per-stage masks (pre, object, ht, trigger).
    pub stages: Vec<Vec<f32>>,
}

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{SkimRuntime, Variant};

// ---------------------------------------------------------------------
// Interpreter-only stub (default build): same surface, no PJRT. The
// engine's `vectorized` path is unreachable because `load` never
// yields a runtime, so the methods below only have to typecheck.
// ---------------------------------------------------------------------

/// One compiled batch-shape variant (stub: never instantiated).
#[cfg(not(feature = "pjrt"))]
pub struct Variant {
    /// Variant name from the manifest.
    pub name: String,
    /// Batch capacity in events.
    pub b: usize,
    /// Object-slot capacity per event.
    pub m: usize,
}

/// The loaded runtime (stub: `load` always errors without the `pjrt`
/// feature; callers fall back to the interpreter).
#[cfg(not(feature = "pjrt"))]
pub struct SkimRuntime {
    /// Kernel capacities from the manifest.
    pub caps: Capacities,
    variants: Vec<Variant>,
}

#[cfg(not(feature = "pjrt"))]
impl SkimRuntime {
    /// Always errors: the crate was built without the `pjrt` feature.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<SkimRuntime> {
        Err(Error::Runtime(format!(
            "cannot load PJRT artifacts from {}: built without the `pjrt` feature \
             (interpreter path only; rebuild with `--features pjrt` and the `xla` crate)",
            dir.as_ref().display()
        )))
    }

    /// `(name, B, M)` of every compiled variant.
    pub fn variants(&self) -> impl Iterator<Item = (&str, usize, usize)> {
        self.variants.iter().map(|v| (v.name.as_str(), v.b, v.m))
    }

    /// Smallest variant whose batch capacity covers `n` events, or the
    /// largest one (caller chunks).
    pub fn variant_for(&self, n: usize) -> &Variant {
        self.variants
            .iter()
            .find(|v| v.b >= n)
            .unwrap_or_else(|| self.variants.last().expect("stub runtime has no variants"))
    }

    /// Variant lookup by name (always errors in stub builds).
    pub fn variant(&self, name: &str) -> Result<&Variant> {
        Err(Error::Runtime(format!(
            "no such variant '{name}': built without the `pjrt` feature"
        )))
    }

    /// Unreachable in stub builds (no runtime can be constructed).
    pub fn eval(
        &self,
        _variant: &Variant,
        _batch: &Batch,
        _params: &CutParams,
    ) -> Result<MaskResult> {
        Err(Error::Runtime(
            "vectorized eval unavailable: built without the `pjrt` feature".into(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::plan::{HtParam, ObjCutParam, ObjGroup, ScalarCutParam};

    /// A program: ≥1 object with col0 > 25 and |col1| < 2.4, HT over
    /// col2 (pt>30) ≥ 100, trigger OR over scalar col 5.
    fn sample_program() -> CutProgram {
        CutProgram {
            obj_columns: vec!["Electron_pt".into(), "Electron_eta".into(), "Jet_pt".into()],
            scalar_columns: vec![
                "nElectron".into(),
                "x1".into(),
                "x2".into(),
                "x3".into(),
                "x4".into(),
                "HLT_IsoMu24".into(),
            ],
            obj_cuts: vec![
                ObjCutParam { col: 0, op: 0, abs: false, value: 25.0 },
                ObjCutParam { col: 1, op: 2, abs: true, value: 2.4 },
            ],
            groups: vec![ObjGroup {
                collection: "Electron".into(),
                cut_range: 0..2,
                min_count: 1,
            }],
            scalar_cuts: vec![ScalarCutParam { col: 0, op: 1, abs: false, value: 1.0 }],
            ht: Some(HtParam { col: 2, object_pt_min: 30.0, min_ht: 100.0 }),
            triggers: vec![5],
            exprs: vec![],
        }
    }

    #[test]
    fn pack_rejects_oversized_programs() {
        let caps = Capacities { c: 12, s: 16, k_obj: 12, k_sc: 6, g: 4, n_stages: 4 };
        let mut program = CutProgram::default();
        for i in 0..13 {
            program.obj_columns.push(format!("c{i}"));
        }
        assert!(CutParams::pack(&program, &caps).is_err());
    }

    #[test]
    fn pack_layout() {
        let caps = Capacities { c: 12, s: 16, k_obj: 12, k_sc: 6, g: 4, n_stages: 4 };
        let params = CutParams::pack(&sample_program(), &caps).unwrap();
        assert_eq!(params.obj_cuts.len(), 60);
        assert_eq!(&params.obj_cuts[..5], &[1.0, 0.0, 0.0, 0.0, 25.0]);
        assert_eq!(&params.obj_cuts[5..10], &[1.0, 1.0, 2.0, 1.0, 2.4]);
        assert_eq!(&params.obj_cuts[10..15], &[0.0; 5]); // unused slot
        assert_eq!(&params.groups[..4], &[1.0, 0.0, 2.0, 1.0]);
        assert_eq!(&params.ht, &[1.0, 2.0, 30.0, 100.0]);
        assert_eq!(params.trig[0], 1.0);
        assert_eq!(params.trig[6], 1.0);
        assert_eq!(params.trig.iter().sum::<f32>(), 2.0);
    }

    #[test]
    fn load_missing_dir_errors() {
        // Without `pjrt` this errors because the feature is off; with
        // it, because the directory does not exist. Either way: Err.
        assert!(SkimRuntime::load("/nonexistent/dir").is_err());
    }
}
