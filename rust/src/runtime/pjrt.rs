//! The real PJRT/XLA backend (compiled only with the `pjrt` feature;
//! requires the `xla` crate — see Cargo.toml).

use super::{Batch, Capacities, CutParams, MaskResult};
use crate::query::Json;
use crate::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One compiled batch-shape variant.
pub struct Variant {
    pub name: String,
    pub b: usize,
    pub m: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// The loaded runtime: PJRT client + compiled variants.
pub struct SkimRuntime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pub caps: Capacities,
    variants: Vec<Variant>,
    /// Serializes [`SkimRuntime::eval`]: the `xla` crate's executables
    /// clone a non-atomic `Rc` of the client per output buffer, so all
    /// refcount manipulation must happen under one lock.
    exec_lock: std::sync::Mutex<()>,
}

// SAFETY: the underlying PJRT C API is thread-safe; the only
// thread-unsafe state on the Rust side is the non-atomic `Rc` refcount
// inside `xla::PjRtClient` / executables. All operations that touch
// those refcounts (load-time compilation, `eval`'s buffer creation and
// destruction) either happen before the runtime is shared or run under
// `exec_lock`. Raw executable pointers are valid for the runtime's
// lifetime.
unsafe impl Send for SkimRuntime {}
unsafe impl Sync for SkimRuntime {}

impl SkimRuntime {
    /// Load `manifest.json` + HLO artifacts from `dir` and compile.
    pub fn load(dir: impl AsRef<Path>) -> Result<SkimRuntime> {
        let dir = dir.as_ref();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let manifest = Json::parse(&text)?;
        let caps_json = manifest.require("capacities")?;
        let get = |k: &str| -> Result<usize> { Ok(caps_json.num_field(k)? as usize) };
        let caps = Capacities {
            c: get("C")?,
            s: get("S")?,
            k_obj: get("K_OBJ")?,
            k_sc: get("K_SC")?,
            g: get("G")?,
            n_stages: get("N_STAGES")?,
        };

        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT CPU client: {e}")))?;
        let mut variants = Vec::new();
        let empty = BTreeMap::new();
        let vmap = manifest
            .require("variants")?
            .as_obj()
            .unwrap_or(&empty);
        for (name, v) in vmap {
            let b = v.num_field("B")? as usize;
            let m = v.num_field("M")? as usize;
            let file = v.str_field("file")?;
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
                Error::Runtime(format!("parse {}: {e}", path.display()))
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
            variants.push(Variant { name: name.clone(), b, m, exe });
        }
        if variants.is_empty() {
            return Err(Error::Runtime("manifest lists no variants".into()));
        }
        variants.sort_by_key(|v| v.b);
        Ok(SkimRuntime { client, caps, variants, exec_lock: std::sync::Mutex::new(()) })
    }

    pub fn variants(&self) -> impl Iterator<Item = (&str, usize, usize)> {
        self.variants.iter().map(|v| (v.name.as_str(), v.b, v.m))
    }

    /// Smallest variant whose batch capacity covers `n` events, or the
    /// largest one (caller chunks).
    pub fn variant_for(&self, n: usize) -> &Variant {
        self.variants
            .iter()
            .find(|v| v.b >= n)
            .unwrap_or_else(|| self.variants.last().expect("nonempty"))
    }

    pub fn variant(&self, name: &str) -> Result<&Variant> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| Error::Runtime(format!("no such variant '{name}'")))
    }

    /// Execute the kernel over one batch.
    pub fn eval(&self, variant: &Variant, batch: &Batch, params: &CutParams) -> Result<MaskResult> {
        let caps = &self.caps;
        if batch.b != variant.b || batch.m != variant.m {
            return Err(Error::Runtime(format!(
                "batch shape ({}, {}) does not match variant {} ({}, {})",
                batch.b, batch.m, variant.name, variant.b, variant.m
            )));
        }
        let lit = |data: &[f32], dims: &[i64]| -> Result<xla::Literal> {
            debug_assert_eq!(data.len() as i64, dims.iter().product::<i64>());
            xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| Error::Runtime(format!("literal reshape: {e}")))
        };
        // Hold the lock for the whole execute → literal extraction span:
        // every PjRtBuffer created/dropped here clones the client Rc.
        let _guard = self.exec_lock.lock().unwrap();
        let args = [
            lit(&batch.cols, &[caps.c as i64, batch.b as i64, batch.m as i64])?,
            lit(&batch.nobj, &[caps.c as i64, batch.b as i64])?,
            lit(&batch.scalars, &[caps.s as i64, batch.b as i64])?,
            lit(&params.obj_cuts, &[caps.k_obj as i64, 5])?,
            lit(&params.groups, &[caps.g as i64, 4])?,
            lit(&params.scalar_cuts, &[caps.k_sc as i64, 5])?,
            lit(&params.ht, &[4])?,
            lit(&params.trig, &[1 + caps.s as i64])?,
        ];
        let result = variant
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| Error::Runtime(format!("execute: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("to_literal: {e}")))?;
        let outs = result
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
        if outs.len() != 5 {
            return Err(Error::Runtime(format!("expected 5 outputs, got {}", outs.len())));
        }
        let mask_full: Vec<f32> = outs[0]
            .to_vec()
            .map_err(|e| Error::Runtime(format!("mask: {e}")))?;
        let stages_full: Vec<f32> = outs[1]
            .to_vec()
            .map_err(|e| Error::Runtime(format!("stages: {e}")))?;
        let n = batch.n_valid.min(batch.b);
        let mask = mask_full[..n].to_vec();
        let stages = (0..caps.n_stages)
            .map(|s| stages_full[s * batch.b..s * batch.b + n].to_vec())
            .collect();
        Ok(MaskResult { mask, stages })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::plan::{CutProgram, HtParam, ObjCutParam, ObjGroup, ScalarCutParam};

    /// A program: ≥1 object with col0 > 25 and |col1| < 2.4, HT over
    /// col2 (pt>30) ≥ 100, trigger OR over scalar col 5.
    fn sample_program() -> CutProgram {
        CutProgram {
            obj_columns: vec!["Electron_pt".into(), "Electron_eta".into(), "Jet_pt".into()],
            scalar_columns: vec![
                "nElectron".into(),
                "x1".into(),
                "x2".into(),
                "x3".into(),
                "x4".into(),
                "HLT_IsoMu24".into(),
            ],
            obj_cuts: vec![
                ObjCutParam { col: 0, op: 0, abs: false, value: 25.0 },
                ObjCutParam { col: 1, op: 2, abs: true, value: 2.4 },
            ],
            groups: vec![ObjGroup {
                collection: "Electron".into(),
                cut_range: 0..2,
                min_count: 1,
            }],
            scalar_cuts: vec![ScalarCutParam { col: 0, op: 1, abs: false, value: 1.0 }],
            ht: Some(HtParam { col: 2, object_pt_min: 30.0, min_ht: 100.0 }),
            triggers: vec![5],
            exprs: vec![],
        }
    }

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    fn runtime() -> SkimRuntime {
        SkimRuntime::load(artifacts_dir()).expect("load artifacts")
    }

    #[test]
    fn load_and_list_variants() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = runtime();
        let names: Vec<_> = rt.variants().map(|(n, _, _)| n.to_string()).collect();
        assert!(names.contains(&"small".to_string()));
        assert!(names.contains(&"large".to_string()));
        assert_eq!(rt.caps.c, 12);
        assert_eq!(rt.caps.n_stages, 4);
        // variant_for picks the smallest fitting batch.
        assert_eq!(rt.variant_for(100).name, "small");
        assert_eq!(rt.variant_for(1000).name, "large");
        assert_eq!(rt.variant_for(100_000).name, "large");
    }

    #[test]
    fn eval_matches_hand_computation() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = runtime();
        let program = sample_program();
        let params = CutParams::pack(&program, &rt.caps).unwrap();
        let variant = rt.variant("small").unwrap();
        let (b, m) = (variant.b, variant.m);
        let mut batch = Batch::zeroed(&rt.caps, b, m);
        batch.n_valid = 3;
        let idx = |c: usize, ev: usize, slot: usize| (c * b + ev) * m + slot;

        // Event 0: passes everything.
        batch.cols[idx(0, 0, 0)] = 30.0; // pt 30 > 25
        batch.cols[idx(1, 0, 0)] = 1.0; // |eta| < 2.4
        batch.nobj[0] = 1.0;
        batch.nobj[b] = 1.0;
        batch.cols[idx(2, 0, 0)] = 120.0; // HT 120 ≥ 100
        batch.nobj[2 * b] = 1.0;
        batch.scalars[0] = 2.0; // nElectron ≥ 1
        batch.scalars[5 * b] = 1.0; // trigger fired

        // Event 1: fails eta.
        batch.cols[idx(0, 1, 0)] = 30.0;
        batch.cols[idx(1, 1, 0)] = 3.0; // |eta| ≥ 2.4
        batch.nobj[1] = 1.0;
        batch.nobj[b + 1] = 1.0;
        batch.cols[idx(2, 1, 0)] = 120.0;
        batch.nobj[2 * b + 1] = 1.0;
        batch.scalars[1] = 1.0;
        batch.scalars[5 * b + 1] = 1.0;

        // Event 2: fails preselection (nElectron = 0).
        batch.scalars[2] = 0.0;
        batch.scalars[5 * b + 2] = 1.0;

        let out = rt.eval(variant, &batch, &params).unwrap();
        assert_eq!(out.mask, vec![1.0, 0.0, 0.0]);
        assert_eq!(out.stages[0], vec![1.0, 1.0, 0.0]); // preselection
        assert_eq!(out.stages[1][1], 0.0); // object stage fails ev 1
    }

    #[test]
    fn eval_empty_program_accepts_all() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = runtime();
        let params = CutParams::pack(&crate::query::plan::CutProgram::default(), &rt.caps).unwrap();
        let variant = rt.variant("small").unwrap();
        let mut batch = Batch::zeroed(&rt.caps, variant.b, variant.m);
        batch.n_valid = 10;
        let out = rt.eval(variant, &batch, &params).unwrap();
        assert_eq!(out.mask.len(), 10);
        assert!(out.mask.iter().all(|&x| x == 1.0));
    }
}
