//! Measurement substrate: the per-operation timeline behind the paper's
//! Figure 4b / 5a breakdowns and the Figure 5b CPU-utilization table.
//!
//! # Hybrid time model
//!
//! The paper measures a **single-threaded, sequential** filtering job,
//! so end-to-end latency decomposes into a sum of stage times. We
//! reproduce it with a hybrid accounting (§Execution-time model of
//! DESIGN.md):
//!
//! * **compute stages run for real** — decompression, deserialization,
//!   filter evaluation and output encoding are actually executed and
//!   wall-clocked ([`Timeline::stage`]);
//! * **transport stages charge virtual time** — network transfers and
//!   disk seeks advance a virtual clock by a modelled duration
//!   ([`Timeline::charge`]) instead of sleeping, so a "1 Gbps WAN"
//!   experiment over gigabytes completes in milliseconds of wall time
//!   while reporting faithful transfer latency.
//!
//! End-to-end latency = Σ stage times (real + virtual).
//! CPU utilization of a node = its real busy time / end-to-end latency,
//! which is exactly what the paper's per-core `top`-style numbers mean.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Pipeline stage, matching the paper's breakdown categories.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Stage {
    /// Reading the file header / metadata.
    OpenMeta,
    /// Fetching compressed baskets (network or disk).
    BasketFetch,
    /// Decompressing basket frames.
    Decompress,
    /// Turning raw basket bytes into typed columns + batch assembly.
    Deserialize,
    /// Evaluating selection criteria (vectorized or interpreted).
    Filter,
    /// Encoding + compressing + writing the output file.
    OutputWrite,
    /// Shipping the filtered file to the client.
    OutputTransfer,
    /// Everything else (resubmission overhead, scheduling delay).
    Other,
}

impl Stage {
    /// Report label for this stage.
    pub fn name(self) -> &'static str {
        match self {
            Stage::OpenMeta => "open/meta",
            Stage::BasketFetch => "basket fetch",
            Stage::Decompress => "decompress",
            Stage::Deserialize => "deserialize",
            Stage::Filter => "filter",
            Stage::OutputWrite => "output write",
            Stage::OutputTransfer => "output transfer",
            Stage::Other => "other",
        }
    }

    /// Every stage, in breakdown-row order.
    pub const ALL: [Stage; 8] = [
        Stage::OpenMeta,
        Stage::BasketFetch,
        Stage::Decompress,
        Stage::Deserialize,
        Stage::Filter,
        Stage::OutputWrite,
        Stage::OutputTransfer,
        Stage::Other,
    ];
}

/// Which machine does the work / pays the CPU time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Node {
    /// The requesting analysis client.
    Client,
    /// The storage server (data-transfer node).
    Server,
    /// The DPU's ARM cores.
    Dpu,
    /// The DPU's hardware decompression engine: busy time is tracked but
    /// does **not** count as ARM-core CPU (the paper's §4 point that the
    /// engine relieves the cores).
    DpuEngine,
}

impl Node {
    /// Report label for this node.
    pub fn name(self) -> &'static str {
        match self {
            Node::Client => "client",
            Node::Server => "server",
            Node::Dpu => "dpu",
            Node::DpuEngine => "dpu-engine",
        }
    }
}

#[derive(Default)]
struct Tables {
    /// seconds per (stage, node) of real compute.
    real: BTreeMap<(Stage, Node), f64>,
    /// seconds per stage of modelled transport time.
    virt: BTreeMap<Stage, f64>,
    /// bytes moved per stage (for tables and sanity checks).
    bytes: BTreeMap<Stage, u64>,
    counters: BTreeMap<&'static str, u64>,
    /// Per-conjunct selectivity tallies keyed by canonical display
    /// string: `(funnel stage, visited, passed, cost_us)`. Recorded
    /// only by the adaptive evaluator; empty otherwise.
    profile: BTreeMap<String, (u8, u64, u64, u64)>,
}

/// One conjunct's selectivity tallies, as reported through
/// `JobReport → JobStatus → wire → HTTP JSON` (see
/// [`Timeline::record_profile`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConjunctProfile {
    /// Canonical conjunct display string (the profile key).
    pub key: String,
    /// Funnel stage the conjunct reports under (0-3).
    pub stage: u8,
    /// Events alive when the conjunct ran.
    pub visited: u64,
    /// Events still alive after it.
    pub passed: u64,
    /// Wall-clock microseconds spent evaluating it.
    pub cost_us: u64,
}

/// Shared, thread-safe stage/latency accounting for one job run.
#[derive(Clone)]
pub struct Timeline {
    inner: Arc<Mutex<Tables>>,
    /// Virtual nanoseconds accumulated by transport charges.
    virt_ns: Arc<AtomicU64>,
}

impl Default for Timeline {
    fn default() -> Self {
        Self::new()
    }
}

impl Timeline {
    /// A fresh, empty timeline.
    pub fn new() -> Self {
        Timeline {
            inner: Arc::new(Mutex::new(Tables::default())),
            virt_ns: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Run `f` as real compute on `node`, attributed to `stage`.
    pub fn stage<T>(&self, stage: Stage, node: Node, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        let mut tab = self.inner.lock().unwrap();
        *tab.real.entry((stage, node)).or_insert(0.0) += dt;
        out
    }

    /// Add already-measured real compute seconds (for work timed
    /// externally, e.g. inside a worker pool).
    pub fn add_real(&self, stage: Stage, node: Node, secs: f64) {
        let mut tab = self.inner.lock().unwrap();
        *tab.real.entry((stage, node)).or_insert(0.0) += secs;
    }

    /// Charge modelled transport time (network / disk) to `stage`.
    pub fn charge(&self, stage: Stage, secs: f64) {
        debug_assert!(secs >= 0.0 && secs.is_finite());
        self.virt_ns
            .fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
        let mut tab = self.inner.lock().unwrap();
        *tab.virt.entry(stage).or_insert(0.0) += secs;
    }

    /// Record bytes moved in `stage`.
    pub fn add_bytes(&self, stage: Stage, bytes: u64) {
        let mut tab = self.inner.lock().unwrap();
        *tab.bytes.entry(stage).or_insert(0) += bytes;
    }

    /// Bump a named counter (round-trips, baskets, cache hits, ...).
    pub fn count(&self, name: &'static str, n: u64) {
        let mut tab = self.inner.lock().unwrap();
        *tab.counters.entry(name).or_insert(0) += n;
    }

    /// Accumulate one conjunct's selectivity tallies under its
    /// canonical display `key` (runtime-owned strings, unlike the
    /// static counter names). The stage of an existing entry is kept —
    /// a conjunct's funnel stage never changes between merges.
    pub fn record_profile(&self, key: &str, stage: u8, visited: u64, passed: u64, cost_us: u64) {
        let mut tab = self.inner.lock().unwrap();
        let e = tab.profile.entry(key.to_string()).or_insert((stage, 0, 0, 0));
        e.1 += visited;
        e.2 += passed;
        e.3 += cost_us;
    }

    /// Snapshot of the per-conjunct selectivity profile, sorted by key
    /// (empty unless the adaptive evaluator ran).
    pub fn profile(&self) -> Vec<ConjunctProfile> {
        let tab = self.inner.lock().unwrap();
        tab.profile
            .iter()
            .map(|(k, &(stage, visited, passed, cost_us))| ConjunctProfile {
                key: k.clone(),
                stage,
                visited,
                passed,
                cost_us,
            })
            .collect()
    }

    /// Fold another timeline's accounting into this one: real compute,
    /// virtual transport, bytes and counters are all added. Used to
    /// fold a parallel branch into the job timeline — e.g. a DPU
    /// fan-out merges only its *critical* (slowest) shard's timeline,
    /// so parallel hardware shows up as latency = max over shards, not
    /// the sum.
    pub fn merge_from(&self, other: &Timeline) {
        if Arc::ptr_eq(&self.inner, &other.inner) {
            return;
        }
        let (real, virt, bytes, counters, profile) = {
            let tab = other.inner.lock().unwrap();
            (
                tab.real.clone(),
                tab.virt.clone(),
                tab.bytes.clone(),
                tab.counters.clone(),
                tab.profile.clone(),
            )
        };
        let mut tab = self.inner.lock().unwrap();
        for ((s, n), v) in real {
            *tab.real.entry((s, n)).or_insert(0.0) += v;
        }
        for (s, v) in virt {
            *tab.virt.entry(s).or_insert(0.0) += v;
        }
        for (s, b) in bytes {
            *tab.bytes.entry(s).or_insert(0) += b;
        }
        for (k, c) in counters {
            *tab.counters.entry(k).or_insert(0) += c;
        }
        for (k, (stage, v, p, c)) in profile {
            let e = tab.profile.entry(k).or_insert((stage, 0, 0, 0));
            e.1 += v;
            e.2 += p;
            e.3 += c;
        }
        self.virt_ns
            .fetch_add(other.virt_ns.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Fold only another timeline's **counters** (and selectivity
    /// profile) into this one. Counters are real work totals
    /// (attempts, cache hits, served bytes) that must be summed across
    /// *all* parallel branches, even when only the critical branch's
    /// modeled time is folded via [`Timeline::merge_from`] — the
    /// dataset layer uses this for its non-critical lanes. Per-conjunct
    /// tallies are the same kind of total, so they ride along.
    pub fn merge_counters_from(&self, other: &Timeline) {
        if Arc::ptr_eq(&self.inner, &other.inner) {
            return;
        }
        let (counters, profile) = {
            let tab = other.inner.lock().unwrap();
            (tab.counters.clone(), tab.profile.clone())
        };
        let mut tab = self.inner.lock().unwrap();
        for (k, c) in counters {
            *tab.counters.entry(k).or_insert(0) += c;
        }
        for (k, (stage, v, p, c)) in profile {
            let e = tab.profile.entry(k).or_insert((stage, 0, 0, 0));
            e.1 += v;
            e.2 += p;
            e.3 += c;
        }
    }

    /// Total stage seconds: real + virtual.
    pub fn stage_total(&self, stage: Stage) -> f64 {
        let tab = self.inner.lock().unwrap();
        let real: f64 = tab
            .real
            .iter()
            .filter(|((s, _), _)| *s == stage)
            .map(|(_, v)| v)
            .sum();
        real + tab.virt.get(&stage).copied().unwrap_or(0.0)
    }

    /// End-to-end latency (sequential model): Σ over stages.
    pub fn elapsed(&self) -> f64 {
        Stage::ALL.iter().map(|&s| self.stage_total(s)).sum()
    }

    /// Real busy seconds attributed to `node`.
    pub fn node_busy(&self, node: Node) -> f64 {
        let tab = self.inner.lock().unwrap();
        tab.real
            .iter()
            .filter(|((_, n), _)| *n == node)
            .map(|(_, v)| v)
            .sum()
    }

    /// CPU utilization of `node` = busy / end-to-end (0..=1).
    pub fn utilization(&self, node: Node) -> f64 {
        let total = self.elapsed();
        if total <= 0.0 {
            return 0.0;
        }
        (self.node_busy(node) / total).min(1.0)
    }

    /// Bytes recorded against `stage`.
    pub fn bytes(&self, stage: Stage) -> u64 {
        let tab = self.inner.lock().unwrap();
        tab.bytes.get(&stage).copied().unwrap_or(0)
    }

    /// Value of the named counter (0 when never bumped).
    pub fn counter(&self, name: &str) -> u64 {
        let tab = self.inner.lock().unwrap();
        tab.counters.get(name).copied().unwrap_or(0)
    }

    /// Snapshot of all counters (sorted by name).
    pub fn counters(&self) -> Vec<(String, u64)> {
        let tab = self.inner.lock().unwrap();
        tab.counters.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    /// A compact per-stage report (used by the CLI, examples and
    /// benches). Includes every named counter — cache hit/miss rates,
    /// round-trips, served bytes — so effectiveness numbers surface in
    /// the end-of-job output rather than staying write-only.
    pub fn report(&self) -> StageReport {
        let mut rows = Vec::new();
        for stage in Stage::ALL {
            let total = self.stage_total(stage);
            if total > 0.0 || self.bytes(stage) > 0 {
                rows.push((stage, total, self.bytes(stage)));
            }
        }
        StageReport {
            rows,
            elapsed: self.elapsed(),
            counters: self.counters(),
            profile: self.profile(),
        }
    }
}

/// Rendered stage breakdown.
#[derive(Debug, Clone)]
pub struct StageReport {
    /// `(stage, seconds, bytes)` rows, zero rows omitted.
    pub rows: Vec<(Stage, f64, u64)>,
    /// End-to-end latency (Σ over stages), seconds.
    pub elapsed: f64,
    /// Named counters, sorted by name (empty entries omitted).
    pub counters: Vec<(String, u64)>,
    /// Per-conjunct selectivity tallies (empty unless the adaptive
    /// evaluator ran).
    pub profile: Vec<ConjunctProfile>,
}

impl std::fmt::Display for StageReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "{:<16} {:>12} {:>12}", "stage", "time", "bytes")?;
        for (stage, secs, bytes) in &self.rows {
            writeln!(
                f,
                "{:<16} {:>12} {:>12}",
                stage.name(),
                crate::util::human_secs(*secs),
                if *bytes > 0 { crate::util::human_bytes(*bytes) } else { "-".into() }
            )?;
        }
        write!(f, "{:<16} {:>12}", "TOTAL", crate::util::human_secs(self.elapsed))?;
        if !self.counters.is_empty() {
            write!(f, "\n\ncounters:")?;
            for (name, value) in &self.counters {
                write!(f, "\n  {name:<24} {value}")?;
            }
        }
        if !self.profile.is_empty() {
            write!(f, "\n\nselectivity profile:")?;
            write!(
                f,
                "\n  {:<5} {:>10} {:>10} {:>8}  {}",
                "stage", "visited", "passed", "pass%", "conjunct"
            )?;
            for p in &self.profile {
                let rate = if p.visited > 0 {
                    format!("{:.1}", 100.0 * p.passed as f64 / p.visited as f64)
                } else {
                    "-".into()
                };
                write!(
                    f,
                    "\n  {:<5} {:>10} {:>10} {:>8}  {}",
                    p.stage, p.visited, p.passed, rate, p.key
                )?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_and_charge_compose() {
        let tl = Timeline::new();
        tl.stage(Stage::Decompress, Node::Client, || {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        tl.charge(Stage::BasketFetch, 2.5);
        assert!(tl.stage_total(Stage::Decompress) >= 0.010);
        assert!((tl.stage_total(Stage::BasketFetch) - 2.5).abs() < 1e-9);
        assert!(tl.elapsed() >= 2.51);
    }

    #[test]
    fn utilization_reflects_busy_fraction() {
        let tl = Timeline::new();
        tl.add_real(Stage::Filter, Node::Dpu, 1.0);
        tl.charge(Stage::BasketFetch, 3.0);
        let u = tl.utilization(Node::Dpu);
        assert!((u - 0.25).abs() < 1e-9, "u={u}");
        assert_eq!(tl.utilization(Node::Client), 0.0);
    }

    #[test]
    fn engine_time_not_cpu_time() {
        let tl = Timeline::new();
        tl.add_real(Stage::Decompress, Node::DpuEngine, 1.0);
        tl.add_real(Stage::Filter, Node::Dpu, 1.0);
        assert!(tl.utilization(Node::Dpu) < 0.51);
        assert!(tl.node_busy(Node::DpuEngine) > 0.99);
    }

    #[test]
    fn bytes_and_counters() {
        let tl = Timeline::new();
        tl.add_bytes(Stage::BasketFetch, 1000);
        tl.add_bytes(Stage::BasketFetch, 24);
        tl.count("round_trips", 3);
        tl.count("round_trips", 2);
        assert_eq!(tl.bytes(Stage::BasketFetch), 1024);
        assert_eq!(tl.counter("round_trips"), 5);
        assert_eq!(tl.counter("missing"), 0);
    }

    #[test]
    fn report_renders() {
        let tl = Timeline::new();
        tl.charge(Stage::BasketFetch, 1.0);
        tl.add_bytes(Stage::BasketFetch, 4096);
        let s = tl.report().to_string();
        assert!(s.contains("basket fetch"));
        assert!(s.contains("TOTAL"));
        assert!(!s.contains("counters"), "no counters section when empty");
        // Named counters surface in the rendered report.
        tl.count("basket_cache_hits", 12);
        let s = tl.report().to_string();
        assert!(s.contains("counters"));
        assert!(s.contains("basket_cache_hits"));
        assert!(s.contains("12"));
    }

    #[test]
    fn profile_records_merges_and_renders() {
        let tl = Timeline::new();
        assert!(tl.profile().is_empty());
        tl.record_profile("MET_pt > 25", 0, 100, 40, 7);
        tl.record_profile("MET_pt > 25", 0, 50, 10, 3);
        // merge_from folds tallies key-wise, like counters.
        let shard = Timeline::new();
        shard.record_profile("MET_pt > 25", 0, 10, 5, 1);
        shard.record_profile("trigger(HLT_IsoMu24)", 3, 55, 54, 2);
        tl.merge_from(&shard);
        // merge_counters_from carries the profile too (non-critical
        // lanes still did real per-conjunct work).
        let lane = Timeline::new();
        lane.record_profile("trigger(HLT_IsoMu24)", 3, 5, 1, 1);
        tl.merge_counters_from(&lane);
        let prof = tl.profile();
        assert_eq!(prof.len(), 2);
        assert_eq!(
            (prof[0].key.as_str(), prof[0].stage, prof[0].visited, prof[0].passed, prof[0].cost_us),
            ("MET_pt > 25", 0, 160, 55, 11)
        );
        assert_eq!((prof[1].visited, prof[1].passed), (60, 55));
        let s = tl.report().to_string();
        assert!(s.contains("selectivity profile"));
        assert!(s.contains("MET_pt > 25"));
        assert!(s.contains("trigger(HLT_IsoMu24)"));
    }

    #[test]
    fn clone_shares_state() {
        let tl = Timeline::new();
        let tl2 = tl.clone();
        tl2.charge(Stage::Other, 1.0);
        assert!((tl.stage_total(Stage::Other) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn merge_from_folds_everything_once() {
        let job = Timeline::new();
        job.charge(Stage::BasketFetch, 1.0);
        let shard = Timeline::new();
        shard.add_real(Stage::Filter, Node::Dpu, 0.5);
        shard.charge(Stage::BasketFetch, 2.0);
        shard.add_bytes(Stage::BasketFetch, 100);
        shard.count("dpu_jobs", 1);
        job.merge_from(&shard);
        assert!((job.stage_total(Stage::BasketFetch) - 3.0).abs() < 1e-9);
        assert!((job.node_busy(Node::Dpu) - 0.5).abs() < 1e-9);
        assert_eq!(job.bytes(Stage::BasketFetch), 100);
        assert_eq!(job.counter("dpu_jobs"), 1);
        // Merging a timeline into itself (same shared state) is a no-op.
        let before = job.elapsed();
        let alias = job.clone();
        job.merge_from(&alias);
        assert!((job.elapsed() - before).abs() < 1e-9);
    }
}
