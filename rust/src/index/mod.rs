//! Zone-map index subsystem: per-basket summaries for basket-level
//! pruning, stored in `.tridx` sidecar files next to their data files.
//!
//! A [`FileIndex`] records, for every basket of every branch, a
//! [`BasketSummary`] — min/max over the basket's values (in the f32
//! domain the filter engine compares in), the value count (events for
//! scalar branches, total objects for jagged ones) and the NaN count.
//! The planner compiles each conjunct of a selection into a
//! [`crate::query::ZonePredicate`]; the engine's fetch stage evaluates
//! those against the index and skips read + decompress + deserialize
//! for clusters that provably contain no passing event (see
//! `engine/pipeline.rs` and ARCHITECTURE.md § "Zone-map index
//! subsystem").
//!
//! Indexes come from two places, guaranteed byte-identical:
//!
//! * [`crate::troot::TRootWriter::finalize`] derives one for free at
//!   write time (the column values are already in memory) and returns
//!   it on the [`crate::troot::writer::WriteSummary`];
//! * [`FileIndex::build_from_file`] re-derives it after the fact for
//!   legacy files (the `skimroot index` CLI command).
//!
//! **Staleness**: the index carries a digest of the data file's
//! metadata footer ([`meta_digest`]). Consumers compare digests before
//! trusting a sidecar; on mismatch the sidecar is ignored with a
//! warning and the engine falls back to a full scan — a stale or
//! corrupt index can cost performance, never correctness.
//!
//! # Sidecar format (`.tridx`)
//!
//! ```text
//! [ 8B magic "TRIDXv1\0" ]
//! [ u32 version = 1 ]
//! [ u64 data-file meta digest ]
//! [ u64 n_events ] [ u32 basket_events ] [ u32 branch count ]
//! per branch:
//!   [ u16 name len ][ name bytes ][ u32 basket count ]
//!   per basket: [ f32 min ][ f32 max ][ u64 n_values ][ u64 n_nan ]
//! [ u64 FNV-1a checksum over all preceding bytes ]
//! ```
//!
//! All integers and floats little-endian. Empty baskets (a jagged
//! branch with zero objects in the cluster) store `min = +inf`,
//! `max = -inf`.

use crate::troot::{ColumnData, ColumnValues, FileMeta, ReadAt, TRootReader};
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// Magic bytes leading every `.tridx` sidecar.
pub const TRIDX_MAGIC: &[u8; 8] = b"TRIDXv1\0";
/// Sidecar format version.
pub const TRIDX_VERSION: u32 = 1;
/// Sidecar file extension (appended to the data file's full name:
/// `events.troot` → `events.troot.tridx`).
pub const SIDECAR_EXT: &str = "tridx";

/// The sidecar path for a data file: the full data filename with
/// `.tridx` appended, in the same directory.
pub fn sidecar_path(data: &Path) -> PathBuf {
    let mut name = data
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_default();
    name.push(".");
    name.push(SIDECAR_EXT);
    data.with_file_name(name)
}

/// True when `name` is a sidecar filename (used by the catalog walker
/// so data-file globs never pick up `.tridx` files).
pub fn is_sidecar_name(name: &str) -> bool {
    name.ends_with(".tridx")
}

/// FNV-1a 64-bit over a byte slice (digests and the sidecar checksum).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Content digest of a data file's metadata footer: FNV-1a over a
/// canonical serialization of event count, codec, basket geometry and
/// every branch's schema + basket index. Cheap (no payload read) and
/// sensitive to any rewrite of the file — rewriting even one basket
/// moves offsets, so a stale sidecar cannot go undetected.
pub fn meta_digest(meta: &FileMeta) -> u64 {
    let mut out = Vec::new();
    out.extend_from_slice(&meta.n_events.to_le_bytes());
    out.push(meta.codec.id());
    out.extend_from_slice(&meta.basket_events.to_le_bytes());
    out.extend_from_slice(&(meta.branches.len() as u32).to_le_bytes());
    for b in &meta.branches {
        out.extend_from_slice(&(b.desc.name.len() as u16).to_le_bytes());
        out.extend_from_slice(b.desc.name.as_bytes());
        out.push(b.desc.dtype.id());
        out.push(match b.desc.kind {
            crate::troot::BranchKind::Scalar => 0,
            crate::troot::BranchKind::Jagged => 1,
        });
        out.extend_from_slice(&(b.desc.group.len() as u16).to_le_bytes());
        out.extend_from_slice(b.desc.group.as_bytes());
        out.extend_from_slice(&(b.baskets.len() as u32).to_le_bytes());
        for k in &b.baskets {
            out.extend_from_slice(&k.offset.to_le_bytes());
            out.extend_from_slice(&k.comp_len.to_le_bytes());
            out.extend_from_slice(&k.raw_len.to_le_bytes());
            out.extend_from_slice(&k.first_event.to_le_bytes());
            out.extend_from_slice(&k.n_events.to_le_bytes());
        }
    }
    fnv1a(&out)
}

/// Zone summary of one basket: value range, value count, NaN count.
///
/// Min/max are computed over the values **converted to f32 exactly as
/// the filter engine converts them** (`engine/batch.rs` casts every
/// scalar dtype with `as f32`), so range tests agree with the
/// interpreter's f32 comparisons at rounding boundaries. NaNs are
/// excluded from the range and counted separately — NaN fails every
/// comparison except `!=`, which [`BasketSummary::may_satisfy`]
/// handles explicitly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BasketSummary {
    /// Smallest non-NaN value (`+inf` when the basket holds none).
    pub min: f32,
    /// Largest non-NaN value (`-inf` when the basket holds none).
    pub max: f32,
    /// Values in the basket: events for a scalar branch, total objects
    /// for a jagged branch.
    pub n_values: u64,
    /// Values that are NaN.
    pub n_nan: u64,
}

impl Default for BasketSummary {
    fn default() -> Self {
        BasketSummary::empty()
    }
}

impl BasketSummary {
    /// The summary of zero values.
    pub fn empty() -> Self {
        BasketSummary {
            min: f32::INFINITY,
            max: f32::NEG_INFINITY,
            n_values: 0,
            n_nan: 0,
        }
    }

    /// Fold one value into the summary.
    pub fn add(&mut self, x: f32) {
        self.n_values += 1;
        if x.is_nan() {
            self.n_nan += 1;
            return;
        }
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    /// Could **any** value in this basket satisfy `cmp(x, op, value)`
    /// (with `|x|` when `abs`)? `op` uses the kernel encoding
    /// (0 `>`, 1 `>=`, 2 `<`, 3 `<=`, 4 `==`, 5 `!=`). Returning
    /// `false` licenses pruning, so every uncertain case answers
    /// `true`; the comparison semantics mirror `engine/interp.rs`
    /// exactly (NaN fails ops 0–4 and passes op 5).
    pub fn may_satisfy(&self, op: u8, abs: bool, value: f32) -> bool {
        if op == 5 && self.n_nan > 0 {
            // A NaN value satisfies `!=` unconditionally.
            return true;
        }
        if self.n_values == self.n_nan {
            // No non-NaN values (or no values at all): ops 0–4 cannot
            // be satisfied, and `!=` was handled above.
            return false;
        }
        let (lo, hi) = if abs {
            if self.min >= 0.0 {
                (self.min, self.max)
            } else if self.max <= 0.0 {
                (-self.max, -self.min)
            } else {
                (0.0, self.max.max(-self.min))
            }
        } else {
            (self.min, self.max)
        };
        match op {
            0 => hi > value,
            1 => hi >= value,
            2 => lo < value,
            3 => lo <= value,
            4 => lo <= value && value <= hi,
            5 => !(lo == hi && hi == value),
            // Unknown op: never prune.
            _ => true,
        }
    }
}

/// Summarize one basket's slice of a full column: events `[lo, hi)`
/// for a scalar column, their objects for a jagged one. This is the
/// single summary routine both index producers share, so writer-derived
/// and reader-derived indexes are byte-identical.
pub fn summarize(col: &ColumnData, lo: usize, hi: usize) -> BasketSummary {
    match col {
        ColumnData::Scalar(v) => summarize_values(v, lo, hi),
        ColumnData::Jagged { offsets, values } => {
            summarize_values(values, offsets[lo] as usize, offsets[hi] as usize)
        }
    }
}

fn summarize_values(v: &ColumnValues, lo: usize, hi: usize) -> BasketSummary {
    let mut s = BasketSummary::empty();
    match v {
        ColumnValues::F32(x) => x[lo..hi].iter().for_each(|&e| s.add(e)),
        ColumnValues::F64(x) => x[lo..hi].iter().for_each(|&e| s.add(e as f32)),
        ColumnValues::I32(x) => x[lo..hi].iter().for_each(|&e| s.add(e as f32)),
        ColumnValues::I64(x) => x[lo..hi].iter().for_each(|&e| s.add(e as f32)),
        ColumnValues::U8(x) => x[lo..hi].iter().for_each(|&e| s.add(e as f32)),
    }
    s
}

/// Zone summaries for every basket of one branch, in basket order
/// (basket index == cluster index: the writer emits exactly one basket
/// per branch per cluster).
#[derive(Debug, Clone, PartialEq)]
pub struct BranchZones {
    /// Branch name.
    pub name: String,
    /// One summary per basket, in event order.
    pub baskets: Vec<BasketSummary>,
}

/// The zone-map index of one data file (the in-memory form of a
/// `.tridx` sidecar).
#[derive(Debug, Clone, PartialEq)]
pub struct FileIndex {
    /// [`meta_digest`] of the data file this index describes; consumers
    /// must verify it against the file's actual metadata before
    /// pruning.
    pub digest: u64,
    /// Events in the data file.
    pub n_events: u64,
    /// Events per basket (cluster size) of the data file.
    pub basket_events: u32,
    /// Per-branch zone summaries, in the data file's schema order.
    pub branches: Vec<BranchZones>,
}

impl FileIndex {
    /// Zone summaries of the named branch.
    pub fn branch(&self, name: &str) -> Option<&BranchZones> {
        self.branches.iter().find(|b| b.name == name)
    }

    /// Summary of one basket of one branch.
    pub fn summary(&self, branch: &str, basket: usize) -> Option<&BasketSummary> {
        self.branch(branch).and_then(|b| b.baskets.get(basket))
    }

    /// Could any value of `branch` in `basket` satisfy the comparison?
    /// Unknown branches or out-of-range baskets answer `true` (never
    /// prune on missing information).
    pub fn may_match(&self, branch: &str, basket: usize, op: u8, abs: bool, value: f32) -> bool {
        match self.summary(branch, basket) {
            Some(s) => s.may_satisfy(op, abs, value),
            None => true,
        }
    }

    /// Derive the index from an open reader by scanning every branch —
    /// the after-the-fact path for legacy files (`skimroot index`).
    /// Byte-identical to the index [`crate::troot::TRootWriter`]
    /// derives at write time: both call [`summarize`] over the same
    /// per-cluster event ranges.
    pub fn build_from_reader<R: ReadAt>(reader: &TRootReader<R>) -> Result<FileIndex> {
        let meta = reader.meta();
        let mut branches = Vec::with_capacity(meta.branches.len());
        for b in &meta.branches {
            let col = reader.read_branch_all(&b.desc.name)?;
            let mut baskets = Vec::with_capacity(b.baskets.len());
            for k in &b.baskets {
                let lo = k.first_event as usize;
                baskets.push(summarize(&col, lo, lo + k.n_events as usize));
            }
            branches.push(BranchZones { name: b.desc.name.clone(), baskets });
        }
        Ok(FileIndex {
            digest: meta_digest(meta),
            n_events: meta.n_events,
            basket_events: meta.basket_events,
            branches,
        })
    }

    /// [`FileIndex::build_from_reader`] over a local file path.
    pub fn build_from_file(path: impl AsRef<Path>) -> Result<FileIndex> {
        let reader = TRootReader::open(crate::troot::LocalFile::open(path)?)?;
        FileIndex::build_from_reader(&reader)
    }

    /// Serialize to the `.tridx` wire format (see the module docs).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(TRIDX_MAGIC);
        out.extend_from_slice(&TRIDX_VERSION.to_le_bytes());
        out.extend_from_slice(&self.digest.to_le_bytes());
        out.extend_from_slice(&self.n_events.to_le_bytes());
        out.extend_from_slice(&self.basket_events.to_le_bytes());
        out.extend_from_slice(&(self.branches.len() as u32).to_le_bytes());
        for b in &self.branches {
            out.extend_from_slice(&(b.name.len() as u16).to_le_bytes());
            out.extend_from_slice(b.name.as_bytes());
            out.extend_from_slice(&(b.baskets.len() as u32).to_le_bytes());
            for s in &b.baskets {
                out.extend_from_slice(&s.min.to_le_bytes());
                out.extend_from_slice(&s.max.to_le_bytes());
                out.extend_from_slice(&s.n_values.to_le_bytes());
                out.extend_from_slice(&s.n_nan.to_le_bytes());
            }
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse the `.tridx` wire format. Any structural damage — bad
    /// magic, unknown version, truncation, checksum mismatch — is an
    /// [`Error::Format`]; callers treat that exactly like a stale
    /// sidecar (warn and full-scan).
    pub fn decode(bytes: &[u8]) -> Result<FileIndex> {
        if bytes.len() < TRIDX_MAGIC.len() + 8 || &bytes[..TRIDX_MAGIC.len()] != TRIDX_MAGIC {
            return Err(Error::format("not a tridx sidecar (bad magic)"));
        }
        let body_len = bytes.len() - 8;
        let stored = u64::from_le_bytes(bytes[body_len..].try_into().unwrap());
        if fnv1a(&bytes[..body_len]) != stored {
            return Err(Error::format("tridx sidecar checksum mismatch"));
        }
        let mut c = Cursor { buf: &bytes[..body_len], pos: TRIDX_MAGIC.len() };
        let version = c.u32()?;
        if version != TRIDX_VERSION {
            return Err(Error::format(format!("unsupported tridx version {version}")));
        }
        let digest = c.u64()?;
        let n_events = c.u64()?;
        let basket_events = c.u32()?;
        let n_branches = c.u32()? as usize;
        let mut branches = Vec::with_capacity(n_branches.min(1 << 20));
        for _ in 0..n_branches {
            let name = c.str16()?;
            let n_baskets = c.u32()? as usize;
            let mut baskets = Vec::with_capacity(n_baskets.min(1 << 20));
            for _ in 0..n_baskets {
                let min = f32::from_le_bytes(c.take(4)?.try_into().unwrap());
                let max = f32::from_le_bytes(c.take(4)?.try_into().unwrap());
                let n_values = c.u64()?;
                let n_nan = c.u64()?;
                baskets.push(BasketSummary { min, max, n_values, n_nan });
            }
            branches.push(BranchZones { name, baskets });
        }
        if c.pos != body_len {
            return Err(Error::format("tridx sidecar has trailing bytes"));
        }
        Ok(FileIndex { digest, n_events, basket_events, branches })
    }

    /// Write the sidecar to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.encode())?;
        Ok(())
    }

    /// Read and parse a sidecar file.
    pub fn load(path: impl AsRef<Path>) -> Result<FileIndex> {
        FileIndex::decode(&std::fs::read(path)?)
    }
}

/// Load the sidecar next to `data` if one exists: `Ok(None)` when the
/// data file has no sidecar, `Err` when a sidecar exists but cannot be
/// parsed (the caller warns and proceeds unindexed).
pub fn load_sidecar(data: &Path) -> Result<Option<FileIndex>> {
    let p = sidecar_path(data);
    if !p.exists() {
        return Ok(None);
    }
    FileIndex::load(&p).map(Some)
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| Error::format("tridx sidecar truncated"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str16(&mut self) -> Result<String> {
        let len = u16::from_le_bytes(self.take(2)?.try_into().unwrap()) as usize;
        String::from_utf8(self.take(len)?.to_vec())
            .map_err(|_| Error::format("tridx sidecar branch name is not UTF-8"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Codec;
    use crate::troot::{BranchDesc, DType, TRootWriter};

    fn dir() -> PathBuf {
        let d = std::env::temp_dir().join(format!("tridx_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_index() -> FileIndex {
        FileIndex {
            digest: 0x1122_3344_5566_7788,
            n_events: 4,
            basket_events: 2,
            branches: vec![
                BranchZones {
                    name: "pt".into(),
                    baskets: vec![
                        BasketSummary { min: -1.5, max: 2.0, n_values: 2, n_nan: 0 },
                        BasketSummary { min: 3.0, max: 8.0, n_values: 2, n_nan: 1 },
                    ],
                },
                BranchZones { name: "n".into(), baskets: vec![BasketSummary::empty()] },
            ],
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let idx = sample_index();
        let bytes = idx.encode();
        assert_eq!(FileIndex::decode(&bytes).unwrap(), idx);
    }

    /// Golden bytes for the v1 sidecar format: an accidental layout
    /// change (field order, widths, checksum) fails here before it can
    /// silently orphan every sidecar in the wild.
    #[test]
    fn golden_file_matches_v1_layout() {
        let golden: Vec<u8> = vec![
            // magic "TRIDXv1\0"
            0x54, 0x52, 0x49, 0x44, 0x58, 0x76, 0x31, 0x00,
            // version 1
            0x01, 0x00, 0x00, 0x00,
            // digest 0x1122334455667788
            0x88, 0x77, 0x66, 0x55, 0x44, 0x33, 0x22, 0x11,
            // n_events 4
            0x04, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // basket_events 2, branch count 2
            0x02, 0x00, 0x00, 0x00, 0x02, 0x00, 0x00, 0x00,
            // branch "pt", 2 baskets
            0x02, 0x00, 0x70, 0x74, 0x02, 0x00, 0x00, 0x00,
            // basket 0: min -1.5, max 2.0, n_values 2, n_nan 0
            0x00, 0x00, 0xc0, 0xbf, 0x00, 0x00, 0x00, 0x40,
            0x02, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // basket 1: min 3.0, max 8.0, n_values 2, n_nan 1
            0x00, 0x00, 0x40, 0x40, 0x00, 0x00, 0x00, 0x41,
            0x02, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            0x01, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // branch "n", 1 empty basket (min +inf, max -inf)
            0x01, 0x00, 0x6e, 0x01, 0x00, 0x00, 0x00,
            0x00, 0x00, 0x80, 0x7f, 0x00, 0x00, 0x80, 0xff,
            0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00,
            // FNV-1a checksum of everything above
            0x45, 0xe1, 0x42, 0x0e, 0x74, 0xd0, 0x47, 0x96,
        ];
        assert_eq!(sample_index().encode(), golden);
        assert_eq!(FileIndex::decode(&golden).unwrap(), sample_index());
    }

    #[test]
    fn decode_rejects_damage() {
        let idx = sample_index();
        let good = idx.encode();
        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        assert!(FileIndex::decode(&bad).is_err());
        // Flipped payload byte → checksum mismatch.
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x01;
        assert!(FileIndex::decode(&bad).is_err());
        // Truncation.
        assert!(FileIndex::decode(&good[..good.len() - 3]).is_err());
        assert!(FileIndex::decode(&good[..4]).is_err());
        // Unknown version (checksum recomputed to isolate the check).
        let mut bad = good[..good.len() - 8].to_vec();
        bad[8] = 9;
        let sum = fnv1a(&bad);
        bad.extend_from_slice(&sum.to_le_bytes());
        let err = FileIndex::decode(&bad).unwrap_err();
        assert!(format!("{err}").contains("version"), "{err}");
    }

    #[test]
    fn may_satisfy_range_ops() {
        let s = BasketSummary { min: 10.0, max: 20.0, n_values: 5, n_nan: 0 };
        // op 0: >
        assert!(s.may_satisfy(0, false, 19.9));
        assert!(!s.may_satisfy(0, false, 20.0));
        // op 1: >=
        assert!(s.may_satisfy(1, false, 20.0));
        assert!(!s.may_satisfy(1, false, 20.1));
        // op 2: <
        assert!(s.may_satisfy(2, false, 10.1));
        assert!(!s.may_satisfy(2, false, 10.0));
        // op 3: <=
        assert!(s.may_satisfy(3, false, 10.0));
        assert!(!s.may_satisfy(3, false, 9.9));
        // op 4: ==
        assert!(s.may_satisfy(4, false, 15.0));
        assert!(!s.may_satisfy(4, false, 25.0));
        assert!(!s.may_satisfy(4, false, 5.0));
        // op 5: != (range is not a single point → some value may differ)
        assert!(s.may_satisfy(5, false, 15.0));
        // Unknown op never prunes.
        assert!(s.may_satisfy(17, false, 1e9));
    }

    #[test]
    fn may_satisfy_abs_straddles_zero() {
        let s = BasketSummary { min: -5.0, max: 3.0, n_values: 4, n_nan: 0 };
        // |x| ranges over [0, 5].
        assert!(s.may_satisfy(0, true, 4.9));
        assert!(!s.may_satisfy(0, true, 5.0));
        assert!(s.may_satisfy(2, true, 0.5));
        assert!(s.may_satisfy(4, true, 4.0));
        assert!(!s.may_satisfy(4, true, 6.0));
        // Entirely negative: |x| ∈ [2, 7].
        let n = BasketSummary { min: -7.0, max: -2.0, n_values: 4, n_nan: 0 };
        assert!(n.may_satisfy(0, true, 6.9));
        assert!(!n.may_satisfy(0, true, 7.0));
        assert!(!n.may_satisfy(2, true, 2.0));
        assert!(n.may_satisfy(2, true, 2.1));
    }

    #[test]
    fn may_satisfy_nan_and_empty() {
        // All-NaN basket: only `!=` can be satisfied.
        let s = BasketSummary { min: f32::INFINITY, max: f32::NEG_INFINITY, n_values: 3, n_nan: 3 };
        for op in 0..5u8 {
            assert!(!s.may_satisfy(op, false, 0.0), "op {op}");
        }
        assert!(s.may_satisfy(5, false, 0.0));
        // Empty basket (no objects in the cluster): nothing satisfies.
        let e = BasketSummary::empty();
        for op in 0..6u8 {
            assert!(!e.may_satisfy(op, false, 0.0), "op {op}");
        }
        // Constant basket: `!=` its value is dead, anything else lives.
        let c = BasketSummary { min: 7.0, max: 7.0, n_values: 4, n_nan: 0 };
        assert!(!c.may_satisfy(5, false, 7.0));
        assert!(c.may_satisfy(5, false, 7.5));
        // ... unless a NaN hides in the basket.
        let cn = BasketSummary { min: 7.0, max: 7.0, n_values: 5, n_nan: 1 };
        assert!(cn.may_satisfy(5, false, 7.0));
    }

    #[test]
    fn summarize_scalar_and_jagged() {
        let col = ColumnData::scalar_f32(vec![3.0, f32::NAN, -1.0, 8.0]);
        let s = summarize(&col, 0, 4);
        assert_eq!(s, BasketSummary { min: -1.0, max: 8.0, n_values: 4, n_nan: 1 });
        let s = summarize(&col, 1, 2);
        assert_eq!(s.n_values, 1);
        assert_eq!(s.n_nan, 1);

        let j = ColumnData::jagged_f32(&[vec![1.0, 2.0], vec![], vec![5.0]]);
        let s = summarize(&j, 0, 2);
        assert_eq!(s, BasketSummary { min: 1.0, max: 2.0, n_values: 2, n_nan: 0 });
        let s = summarize(&j, 1, 2);
        assert_eq!(s, BasketSummary::empty());
    }

    #[test]
    fn writer_and_reader_derived_indexes_agree() {
        let d = dir();
        let path = d.join("agree.troot");
        let mut w = TRootWriter::new(&path, Codec::Lz4, 3);
        w.add_branch(
            BranchDesc::scalar("met", DType::F32),
            ColumnData::scalar_f32(vec![5.0, -2.0, 9.0, 1.0, 4.0, 6.0, 0.0]),
        )
        .unwrap();
        w.add_branch(
            BranchDesc::jagged("Jet_pt", DType::F32, "Jet"),
            ColumnData::jagged_f32(&[
                vec![30.0, 12.0],
                vec![],
                vec![55.0],
                vec![18.0, 44.0, 2.0],
                vec![],
                vec![],
                vec![7.0],
            ]),
        )
        .unwrap();
        w.add_branch(
            BranchDesc::scalar("run", DType::I64),
            ColumnData::Scalar(ColumnValues::I64(vec![1, 1, 1, 2, 2, 2, 2])),
        )
        .unwrap();
        let summary = w.finalize().unwrap();
        let derived = FileIndex::build_from_file(&path).unwrap();
        assert_eq!(summary.index, derived);
        assert_eq!(summary.index.encode(), derived.encode());
        // 7 events at 3 per basket → 3 baskets per branch.
        assert_eq!(derived.branch("met").unwrap().baskets.len(), 3);
        // Jagged summaries count objects, not events.
        let jets = derived.branch("Jet_pt").unwrap();
        assert_eq!(jets.baskets[0].n_values, 2);
        assert_eq!(jets.baskets[1].n_values, 4);
        assert_eq!(jets.baskets[2].n_values, 1);
        // Digest matches the file it came from.
        let r = TRootReader::open(crate::troot::LocalFile::open(&path).unwrap()).unwrap();
        assert_eq!(derived.digest, meta_digest(r.meta()));
    }

    #[test]
    fn save_load_and_sidecar_paths() {
        let d = dir();
        let data = d.join("events.troot");
        let side = sidecar_path(&data);
        assert_eq!(side.file_name().unwrap(), "events.troot.tridx");
        assert!(is_sidecar_name("events.troot.tridx"));
        assert!(!is_sidecar_name("events.troot"));
        let idx = sample_index();
        idx.save(&side).unwrap();
        assert_eq!(FileIndex::load(&side).unwrap(), idx);
        assert_eq!(load_sidecar(&data).unwrap().unwrap(), idx);
        assert!(load_sidecar(&d.join("absent.troot")).unwrap().is_none());
        // A corrupt sidecar is an error (callers warn + full-scan).
        std::fs::write(&side, b"garbage").unwrap();
        assert!(load_sidecar(&data).is_err());
        let _ = std::fs::remove_file(&side);
    }

    #[test]
    fn digest_tracks_rewrites() {
        let d = dir();
        let path = d.join("digest.troot");
        let write = |vals: Vec<f32>| {
            let mut w = TRootWriter::new(&path, Codec::Lz4, 2);
            w.add_branch(BranchDesc::scalar("x", DType::F32), ColumnData::scalar_f32(vals))
                .unwrap();
            w.finalize().unwrap()
        };
        let a = write(vec![1.0, 2.0, 3.0]);
        let b = write(vec![1.0, 2.0, 3.0, 4.0]);
        assert_ne!(a.index.digest, b.index.digest);
    }
}
