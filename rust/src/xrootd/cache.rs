//! TTreeCache: trained prefetching of basket ranges (§2.2).
//!
//! ROOT's TTreeCache watches which branches a job reads, then fetches
//! the upcoming baskets of those branches for a window of entries in
//! one `readv` — turning thousands of small high-latency reads into a
//! few bulk transfers.
//!
//! This implementation takes the access plan explicitly (`train`):
//! the engine knows exactly which baskets phase 1 / phase 2 will touch.
//! On a miss for a planned range, the cache issues one vector read for
//! the next window of planned ranges that fits in `capacity`, evicting
//! the previous window (the job streams forward; consumed baskets are
//! dead).
//!
//! Two paper-relevant behaviours:
//! * hits avoid round-trips entirely — the Figure 4a/4b fetch savings;
//! * the cache is a client-side object: **local** reads (server-side
//!   filtering) don't get one, which is why Figure 5a shows 18 s of
//!   per-basket fetch there ("TTreeCache does not function for local
//!   ROOT file access").

use crate::troot::ReadAt;
use crate::Result;
use std::collections::HashMap;
use std::sync::Mutex;

/// Prefetching cache over any [`ReadAt`] store.
pub struct TTreeCache<R: ReadAt> {
    store: R,
    capacity: usize,
    state: Mutex<State>,
}

#[derive(Default)]
struct State {
    /// Planned ranges in consumption order (sorted by offset at train).
    plan: Vec<(u64, usize)>,
    /// Index of the first not-yet-prefetched plan entry.
    next: usize,
    /// offset → bytes for the currently cached window.
    window: HashMap<u64, Vec<u8>>,
    window_bytes: usize,
    stats: CacheStats,
}

/// Cache effectiveness counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Planned reads served from the cached window.
    pub hits: u64,
    /// Planned reads that triggered a prefetch.
    pub misses: u64,
    /// Reads not covered by the plan (metadata, unplanned baskets).
    pub passthrough: u64,
    /// Vector reads issued.
    pub prefetch_batches: u64,
    /// Total bytes prefetched over the cache's lifetime.
    pub prefetched_bytes: u64,
}

impl<R: ReadAt> TTreeCache<R> {
    /// A cache over `store` prefetching up to `capacity` bytes per
    /// window.
    pub fn new(store: R, capacity: usize) -> Self {
        TTreeCache { store, capacity: capacity.max(1), state: Mutex::new(State::default()) }
    }

    /// Install the basket access plan. Ranges are sorted by offset
    /// (XRootD sorts readv requests; file order is stream order for
    /// cluster-interleaved layouts). Resets the cached window, keeps
    /// lifetime stats.
    pub fn train(&self, mut ranges: Vec<(u64, usize)>) {
        ranges.sort_unstable();
        ranges.dedup();
        let mut st = self.state.lock().unwrap();
        st.plan = ranges;
        st.next = 0;
        st.window.clear();
        st.window_bytes = 0;
    }

    /// Lifetime effectiveness counters.
    pub fn stats(&self) -> CacheStats {
        self.state.lock().unwrap().stats
    }

    /// The wrapped store.
    pub fn store(&self) -> &R {
        &self.store
    }

    /// Prefetch the next window of planned ranges, starting no earlier
    /// than the entry covering `want_offset` (skips already-consumed
    /// plan entries when the reader jumps forward).
    fn prefetch_from(&self, st: &mut State, want_offset: u64) -> Result<()> {
        // Advance to the plan entry for want_offset (plan is sorted).
        while st.next < st.plan.len() && st.plan[st.next].0 < want_offset {
            st.next += 1;
        }
        // The previous window is dead: the job streams forward.
        st.window.clear();
        st.window_bytes = 0;

        let mut batch = Vec::new();
        let mut bytes = 0usize;
        while st.next < st.plan.len() {
            let (off, len) = st.plan[st.next];
            if !batch.is_empty() && bytes + len > self.capacity {
                break;
            }
            batch.push((off, len));
            bytes += len;
            st.next += 1;
        }
        if batch.is_empty() {
            return Ok(());
        }
        let chunks = self.store.read_vec(&batch)?;
        st.stats.prefetch_batches += 1;
        st.stats.prefetched_bytes += bytes as u64;
        for ((off, _), chunk) in batch.into_iter().zip(chunks) {
            st.window_bytes += chunk.len();
            st.window.insert(off, chunk);
        }
        Ok(())
    }
}

impl<R: ReadAt> ReadAt for TTreeCache<R> {
    fn read_at(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
        let mut st = self.state.lock().unwrap();
        if let Some(chunk) = st.window.get(&offset) {
            if chunk.len() >= len {
                let out = chunk[..len].to_vec();
                st.stats.hits += 1;
                return Ok(out);
            }
        }
        // Planned? (exact-offset match is what the engine issues)
        let planned = st.plan.binary_search(&(offset, len)).is_ok();
        if !planned {
            st.stats.passthrough += 1;
            drop(st);
            return self.store.read_at(offset, len);
        }
        st.stats.misses += 1;
        self.prefetch_from(&mut st, offset)?;
        match st.window.get(&offset) {
            Some(chunk) if chunk.len() >= len => Ok(chunk[..len].to_vec()),
            _ => {
                // Plan raced or capacity smaller than one basket: direct.
                drop(st);
                self.store.read_at(offset, len)
            }
        }
    }

    fn read_vec(&self, ranges: &[(u64, usize)]) -> Result<Vec<Vec<u8>>> {
        ranges.iter().map(|&(o, l)| self.read_at(o, l)).collect()
    }

    fn size(&self) -> Result<u64> {
        self.store.size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// In-memory store counting round-trips.
    struct MemStore {
        data: Vec<u8>,
        reads: AtomicU64,
        readvs: AtomicU64,
    }

    impl MemStore {
        fn new(n: usize) -> Self {
            MemStore {
                data: (0..n).map(|i| (i % 251) as u8).collect(),
                reads: AtomicU64::new(0),
                readvs: AtomicU64::new(0),
            }
        }
    }

    impl ReadAt for MemStore {
        fn read_at(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
            self.reads.fetch_add(1, Ordering::Relaxed);
            Ok(self.data[offset as usize..offset as usize + len].to_vec())
        }

        fn read_vec(&self, ranges: &[(u64, usize)]) -> Result<Vec<Vec<u8>>> {
            self.readvs.fetch_add(1, Ordering::Relaxed);
            Ok(ranges
                .iter()
                .map(|&(o, l)| self.data[o as usize..o as usize + l].to_vec())
                .collect())
        }

        fn size(&self) -> Result<u64> {
            Ok(self.data.len() as u64)
        }
    }

    #[test]
    fn trained_reads_batch_round_trips() {
        let store = MemStore::new(100_000);
        let cache = TTreeCache::new(store, 1 << 20);
        let plan: Vec<(u64, usize)> = (0..50).map(|i| (i * 2000, 1000usize)).collect();
        cache.train(plan.clone());
        for &(o, l) in &plan {
            let got = cache.read_at(o, l).unwrap();
            assert_eq!(got.len(), l);
            assert_eq!(got[0], ((o as usize) % 251) as u8);
        }
        let stats = cache.stats();
        assert_eq!(stats.misses, 1); // only the first touch misses
        assert_eq!(stats.hits, 49);
        assert_eq!(stats.prefetch_batches, 1); // all 50 KB fit in 1 MiB
        assert_eq!(cache.store().readvs.load(Ordering::Relaxed), 1);
        assert_eq!(cache.store().reads.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn capacity_splits_prefetch_windows() {
        let store = MemStore::new(100_000);
        let cache = TTreeCache::new(store, 3000); // 3 baskets per window
        let plan: Vec<(u64, usize)> = (0..9).map(|i| (i * 5000, 1000usize)).collect();
        cache.train(plan.clone());
        for &(o, l) in &plan {
            cache.read_at(o, l).unwrap();
        }
        let stats = cache.stats();
        assert_eq!(stats.prefetch_batches, 3);
        assert_eq!(stats.misses, 3);
        assert_eq!(stats.hits, 6);
    }

    #[test]
    fn unplanned_reads_pass_through() {
        let store = MemStore::new(10_000);
        let cache = TTreeCache::new(store, 1 << 20);
        cache.train(vec![(0, 100)]);
        cache.read_at(5000, 10).unwrap();
        let stats = cache.stats();
        assert_eq!(stats.passthrough, 1);
        assert_eq!(cache.store().reads.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn retrain_resets_window() {
        let store = MemStore::new(10_000);
        let cache = TTreeCache::new(store, 1 << 20);
        cache.train(vec![(0, 100), (200, 100)]);
        cache.read_at(0, 100).unwrap();
        assert_eq!(cache.stats().prefetch_batches, 1);
        cache.train(vec![(400, 100)]);
        cache.read_at(400, 100).unwrap();
        let stats = cache.stats();
        assert_eq!(stats.prefetch_batches, 2);
        assert_eq!(stats.misses, 2);
    }

    #[test]
    fn jumping_forward_skips_consumed_plan() {
        let store = MemStore::new(100_000);
        let cache = TTreeCache::new(store, 2000);
        let plan: Vec<(u64, usize)> = (0..10).map(|i| (i * 1000, 1000usize)).collect();
        cache.train(plan);
        // Jump straight to the 6th basket — earlier entries are skipped.
        let got = cache.read_at(5000, 1000).unwrap();
        assert_eq!(got.len(), 1000);
        // Next planned basket is prefetched with it (2000 B window).
        assert!(cache.read_at(6000, 1000).is_ok());
        let stats = cache.stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.hits, 1);
    }

    #[test]
    fn basket_larger_than_capacity_still_served() {
        let store = MemStore::new(100_000);
        let cache = TTreeCache::new(store, 10); // absurdly small
        cache.train(vec![(0, 5000)]);
        let got = cache.read_at(0, 5000).unwrap();
        assert_eq!(got.len(), 5000);
    }
}
