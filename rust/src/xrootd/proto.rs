//! Wire protocol: request/response types and binary framing.
//!
//! Framing: `u32 payload_len (LE) | u8 opcode | fields...`. Strings are
//! `u16 len + bytes`; range vectors are `u32 count + (u64 off, u32 len)*`.

use crate::{Error, Result};

/// Upper bound on one frame's payload (read and write side).
pub const MAX_FRAME: usize = 512 * 1024 * 1024;

/// Tighter bound servers apply to **inbound request** frames. Requests
/// are small (paths, queries, job ids) — only responses legitimately
/// carry file-sized payloads, plus `Put` uploads of filtered outputs.
/// A remote peer claiming a larger request is malformed or malicious;
/// the server drops that connection without reading (or allocating)
/// the claimed length.
pub const MAX_REQUEST_FRAME: usize = 64 * 1024 * 1024;

/// Client → server request (see the module docs for the framing).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Open a file by (catalog-relative) path.
    Open {
        /// Catalog-relative file path.
        path: String,
    },
    /// File size of an open handle.
    Stat {
        /// Handle returned by [`Response::Opened`].
        fd: u32,
    },
    /// Positioned read.
    Read {
        /// Handle returned by [`Response::Opened`].
        fd: u32,
        /// Absolute byte offset.
        offset: u64,
        /// Bytes to read.
        len: u32,
    },
    /// Vector read: many ranges, one round-trip.
    ReadV {
        /// Handle returned by [`Response::Opened`].
        fd: u32,
        /// `(offset, len)` ranges to fetch.
        ranges: Vec<(u64, u32)>,
    },
    /// Release an open handle.
    Close {
        /// Handle returned by [`Response::Opened`].
        fd: u32,
    },
    /// Upload a file (the DPU ships the filtered output back through
    /// the same protocol).
    Put {
        /// Catalog-relative destination path.
        path: String,
        /// File contents.
        data: Vec<u8>,
    },
    /// Submit a skim job to a multi-tenant service
    /// ([`crate::serve::SkimService`]); answered by
    /// [`Response::JobAccepted`] or an admission-control error.
    SubmitQuery {
        /// The JSON query payload ([`crate::query::SkimQuery`]).
        query_json: String,
        /// Virtual-time deadline in milliseconds (`0` = none): the job
        /// ends [`crate::serve::JobState::DeadlineExceeded`] once its
        /// modeled latency passes this.
        deadline_ms: u64,
    },
    /// Poll a submitted job; answered by [`Response::JobState`].
    JobStatus {
        /// Id from [`Response::JobAccepted`].
        job: u64,
    },
    /// Fetch a finished job's filtered-file bytes; answered by
    /// [`Response::Data`].
    FetchResult {
        /// Id from [`Response::JobAccepted`].
        job: u64,
    },
    /// List the files a dataset spec resolves to on this server
    /// (glob, `catalog:NAME`, single file) — how remote clients
    /// preview and submit dataset queries by name. Answered by
    /// [`Response::Listing`].
    ListCatalog {
        /// Dataset-spec spelling ([`crate::query::DatasetSpec`]).
        spec: String,
    },
    /// Cancel a submitted job ([`crate::serve::SkimScheduler::cancel`]
    /// semantics: queued jobs flip terminal immediately, running jobs
    /// stop at the next basket-group boundary, terminal jobs are
    /// untouched). Answered by [`Response::JobState`] with the
    /// post-cancel status.
    CancelJob {
        /// Id from [`Response::JobAccepted`].
        job: u64,
    },
}

/// Server → client reply, paired with the [`Request`] opcodes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    /// File opened.
    Opened {
        /// Handle for subsequent reads.
        fd: u32,
        /// File size in bytes.
        size: u64,
    },
    /// Answer to [`Request::Stat`].
    Stats {
        /// File size in bytes.
        size: u64,
    },
    /// Payload of a positioned read (or a fetched job result).
    Data {
        /// The requested bytes.
        data: Vec<u8>,
    },
    /// Payload of a vector read, one chunk per requested range.
    DataV {
        /// Chunks in request order.
        chunks: Vec<Vec<u8>>,
    },
    /// Acknowledgement with no payload.
    Done,
    /// Request failed; the connection stays usable.
    Error {
        /// Human-readable failure description.
        msg: String,
    },
    /// A submitted skim job was admitted to the queue.
    JobAccepted {
        /// Service-assigned job id.
        job: u64,
    },
    /// Current state of a submitted job
    /// ([`crate::serve::JobState::code`] codes).
    JobState {
        /// Coarse state code (queued / running / done / failed).
        state: u8,
        /// Events the finished job covered (0 while in flight).
        n_events: u64,
        /// Events passing the selection (0 while in flight).
        n_pass: u64,
        /// Modeled end-to-end latency in microseconds (0 in flight).
        latency_us: u64,
        /// Shared basket-cache hits the job scored.
        cache_hits: u64,
        /// Shared basket-cache misses the job paid for.
        cache_misses: u64,
        /// Criteria baskets skipped by zone-map pruning.
        baskets_pruned: u64,
        /// Criteria baskets actually read (`baskets_pruned +
        /// baskets_scanned` is the full criteria scan).
        baskets_scanned: u64,
        /// Decoded-basket views received from a shared batch scan
        /// instead of fetched by this job itself (0 for solo runs).
        scan_shared: u64,
        /// Shared-scan batch id the job ran in (0 = not batched).
        batch_id: u64,
        /// Member jobs that batch's one scan served (0 = not batched).
        batch_members: u64,
        /// Dataset files completed successfully so far.
        files_done: u64,
        /// Files in the job's dataset (0 for single-file jobs).
        files_total: u64,
        /// Resubmission attempts beyond the first across the job's
        /// retry loops.
        retries: u64,
        /// Faults injected into the job's reads (chaos runs only).
        faults_injected: u64,
        /// Retry backoff charged to virtual time, microseconds.
        backoff_us: u64,
        /// 1 when the job ended cancelled.
        cancelled: u64,
        /// 1 when the job ended deadline-exceeded.
        deadline_exceeded: u64,
        /// Failure message (empty unless the job ended with an error).
        msg: String,
        /// Per-file failure detail (`"<path>: <error>"`) for
        /// fault-isolated dataset file failures.
        file_errors: Vec<String>,
        /// Per-conjunct selectivity tallies from the adaptive
        /// evaluator, `(key, stage, visited, passed, cost_us)` —
        /// empty unless the deployment ran adaptive execution.
        profile: Vec<(String, u8, u64, u64, u64)>,
    },
    /// Answer to [`Request::ListCatalog`]: the resolved file list, in
    /// dataset order.
    Listing {
        /// Catalog-relative files the spec resolved to.
        files: Vec<String>,
    },
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let s = self
            .buf
            .get(self.pos..self.pos + n)
            .ok_or_else(|| Error::protocol("truncated frame"))?;
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| Error::protocol("invalid utf-8"))
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME {
            return Err(Error::protocol("oversized byte field"));
        }
        Ok(self.take(n)?.to_vec())
    }

    fn finished(&self) -> bool {
        self.pos == self.buf.len()
    }
}

impl Request {
    /// Serialize to the wire form (opcode + fields).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Request::Open { path } => {
                out.push(1);
                put_str(&mut out, path);
            }
            Request::Stat { fd } => {
                out.push(2);
                out.extend_from_slice(&fd.to_le_bytes());
            }
            Request::Read { fd, offset, len } => {
                out.push(3);
                out.extend_from_slice(&fd.to_le_bytes());
                out.extend_from_slice(&offset.to_le_bytes());
                out.extend_from_slice(&len.to_le_bytes());
            }
            Request::ReadV { fd, ranges } => {
                out.push(4);
                out.extend_from_slice(&fd.to_le_bytes());
                out.extend_from_slice(&(ranges.len() as u32).to_le_bytes());
                for (o, l) in ranges {
                    out.extend_from_slice(&o.to_le_bytes());
                    out.extend_from_slice(&l.to_le_bytes());
                }
            }
            Request::Close { fd } => {
                out.push(5);
                out.extend_from_slice(&fd.to_le_bytes());
            }
            Request::Put { path, data } => {
                out.push(6);
                put_str(&mut out, path);
                put_bytes(&mut out, data);
            }
            Request::SubmitQuery { query_json, deadline_ms } => {
                // u32-length bytes, not a u16 string: query payloads
                // with large branch lists can exceed 64 KiB.
                out.push(7);
                put_bytes(&mut out, query_json.as_bytes());
                out.extend_from_slice(&deadline_ms.to_le_bytes());
            }
            Request::JobStatus { job } => {
                out.push(8);
                out.extend_from_slice(&job.to_le_bytes());
            }
            Request::FetchResult { job } => {
                out.push(9);
                out.extend_from_slice(&job.to_le_bytes());
            }
            Request::ListCatalog { spec } => {
                out.push(10);
                put_str(&mut out, spec);
            }
            Request::CancelJob { job } => {
                out.push(11);
                out.extend_from_slice(&job.to_le_bytes());
            }
        }
        out
    }

    /// Parse one frame payload; rejects trailing bytes.
    pub fn decode(buf: &[u8]) -> Result<Request> {
        let mut c = Cursor::new(buf);
        let req = match c.u8()? {
            1 => Request::Open { path: c.str()? },
            2 => Request::Stat { fd: c.u32()? },
            3 => Request::Read { fd: c.u32()?, offset: c.u64()?, len: c.u32()? },
            4 => {
                let fd = c.u32()?;
                let n = c.u32()? as usize;
                if n > 4_000_000 {
                    return Err(Error::protocol("too many readv ranges"));
                }
                let mut ranges = Vec::with_capacity(n);
                for _ in 0..n {
                    ranges.push((c.u64()?, c.u32()?));
                }
                Request::ReadV { fd, ranges }
            }
            5 => Request::Close { fd: c.u32()? },
            6 => Request::Put { path: c.str()?, data: c.bytes()? },
            7 => Request::SubmitQuery {
                query_json: String::from_utf8(c.bytes()?)
                    .map_err(|_| Error::protocol("invalid utf-8 in query"))?,
                deadline_ms: c.u64()?,
            },
            8 => Request::JobStatus { job: c.u64()? },
            9 => Request::FetchResult { job: c.u64()? },
            10 => Request::ListCatalog { spec: c.str()? },
            11 => Request::CancelJob { job: c.u64()? },
            op => return Err(Error::protocol(format!("bad request opcode {op}"))),
        };
        if !c.finished() {
            return Err(Error::protocol("trailing bytes in request"));
        }
        Ok(req)
    }
}

impl Response {
    /// Serialize to the wire form (opcode + fields).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Response::Opened { fd, size } => {
                out.push(1);
                out.extend_from_slice(&fd.to_le_bytes());
                out.extend_from_slice(&size.to_le_bytes());
            }
            Response::Stats { size } => {
                out.push(2);
                out.extend_from_slice(&size.to_le_bytes());
            }
            Response::Data { data } => {
                out.push(3);
                put_bytes(&mut out, data);
            }
            Response::DataV { chunks } => {
                out.push(4);
                out.extend_from_slice(&(chunks.len() as u32).to_le_bytes());
                for ch in chunks {
                    put_bytes(&mut out, ch);
                }
            }
            Response::Done => out.push(5),
            Response::Error { msg } => {
                out.push(6);
                put_str(&mut out, msg);
            }
            Response::JobAccepted { job } => {
                out.push(7);
                out.extend_from_slice(&job.to_le_bytes());
            }
            Response::JobState {
                state,
                n_events,
                n_pass,
                latency_us,
                cache_hits,
                cache_misses,
                baskets_pruned,
                baskets_scanned,
                scan_shared,
                batch_id,
                batch_members,
                files_done,
                files_total,
                retries,
                faults_injected,
                backoff_us,
                cancelled,
                deadline_exceeded,
                msg,
                file_errors,
                profile,
            } => {
                out.push(8);
                out.push(*state);
                out.extend_from_slice(&n_events.to_le_bytes());
                out.extend_from_slice(&n_pass.to_le_bytes());
                out.extend_from_slice(&latency_us.to_le_bytes());
                out.extend_from_slice(&cache_hits.to_le_bytes());
                out.extend_from_slice(&cache_misses.to_le_bytes());
                out.extend_from_slice(&baskets_pruned.to_le_bytes());
                out.extend_from_slice(&baskets_scanned.to_le_bytes());
                out.extend_from_slice(&scan_shared.to_le_bytes());
                out.extend_from_slice(&batch_id.to_le_bytes());
                out.extend_from_slice(&batch_members.to_le_bytes());
                out.extend_from_slice(&files_done.to_le_bytes());
                out.extend_from_slice(&files_total.to_le_bytes());
                out.extend_from_slice(&retries.to_le_bytes());
                out.extend_from_slice(&faults_injected.to_le_bytes());
                out.extend_from_slice(&backoff_us.to_le_bytes());
                out.extend_from_slice(&cancelled.to_le_bytes());
                out.extend_from_slice(&deadline_exceeded.to_le_bytes());
                put_str(&mut out, msg);
                // u32 count: thousand-file catalogs can fail per file
                // far beyond a u16's range.
                out.extend_from_slice(&(file_errors.len() as u32).to_le_bytes());
                for e in file_errors {
                    put_str(&mut out, e);
                }
                out.extend_from_slice(&(profile.len() as u32).to_le_bytes());
                for (key, stage, visited, passed, cost_us) in profile {
                    put_str(&mut out, key);
                    out.push(*stage);
                    out.extend_from_slice(&visited.to_le_bytes());
                    out.extend_from_slice(&passed.to_le_bytes());
                    out.extend_from_slice(&cost_us.to_le_bytes());
                }
            }
            Response::Listing { files } => {
                out.push(9);
                out.extend_from_slice(&(files.len() as u32).to_le_bytes());
                for f in files {
                    put_str(&mut out, f);
                }
            }
        }
        out
    }

    /// Parse one frame payload; rejects trailing bytes.
    pub fn decode(buf: &[u8]) -> Result<Response> {
        let mut c = Cursor::new(buf);
        let resp = match c.u8()? {
            1 => Response::Opened { fd: c.u32()?, size: c.u64()? },
            2 => Response::Stats { size: c.u64()? },
            3 => Response::Data { data: c.bytes()? },
            4 => {
                let n = c.u32()? as usize;
                if n > 4_000_000 {
                    return Err(Error::protocol("too many readv chunks"));
                }
                let mut chunks = Vec::with_capacity(n);
                for _ in 0..n {
                    chunks.push(c.bytes()?);
                }
                Response::DataV { chunks }
            }
            5 => Response::Done,
            6 => Response::Error { msg: c.str()? },
            7 => Response::JobAccepted { job: c.u64()? },
            8 => {
                let state = c.u8()?;
                let n_events = c.u64()?;
                let n_pass = c.u64()?;
                let latency_us = c.u64()?;
                let cache_hits = c.u64()?;
                let cache_misses = c.u64()?;
                let baskets_pruned = c.u64()?;
                let baskets_scanned = c.u64()?;
                let scan_shared = c.u64()?;
                let batch_id = c.u64()?;
                let batch_members = c.u64()?;
                let files_done = c.u64()?;
                let files_total = c.u64()?;
                let retries = c.u64()?;
                let faults_injected = c.u64()?;
                let backoff_us = c.u64()?;
                let cancelled = c.u64()?;
                let deadline_exceeded = c.u64()?;
                let msg = c.str()?;
                let n = c.u32()? as usize;
                if n > 1_000_000 {
                    return Err(Error::protocol("too many file errors"));
                }
                let mut file_errors = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    file_errors.push(c.str()?);
                }
                let n = c.u32()? as usize;
                if n > 100_000 {
                    return Err(Error::protocol("too many profile entries"));
                }
                let mut profile = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    let key = c.str()?;
                    let stage = c.u8()?;
                    let visited = c.u64()?;
                    let passed = c.u64()?;
                    let cost_us = c.u64()?;
                    profile.push((key, stage, visited, passed, cost_us));
                }
                Response::JobState {
                    state,
                    n_events,
                    n_pass,
                    latency_us,
                    cache_hits,
                    cache_misses,
                    baskets_pruned,
                    baskets_scanned,
                    scan_shared,
                    batch_id,
                    batch_members,
                    files_done,
                    files_total,
                    retries,
                    faults_injected,
                    backoff_us,
                    cancelled,
                    deadline_exceeded,
                    msg,
                    file_errors,
                    profile,
                }
            }
            9 => {
                let n = c.u32()? as usize;
                if n > 1_000_000 {
                    return Err(Error::protocol("too many listing entries"));
                }
                // Cap the preallocation: the count is attacker-
                // controlled and precedes any validated payload.
                let mut files = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    files.push(c.str()?);
                }
                Response::Listing { files }
            }
            op => return Err(Error::protocol(format!("bad response opcode {op}"))),
        };
        if !c.finished() {
            return Err(Error::protocol("trailing bytes in response"));
        }
        Ok(resp)
    }
}

/// Write one length-prefixed frame to a stream.
pub fn write_frame(w: &mut impl std::io::Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(Error::protocol("frame too large"));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame from a stream.
pub fn read_frame(r: &mut impl std::io::Read) -> Result<Vec<u8>> {
    read_frame_capped(r, MAX_FRAME)
}

/// [`read_frame`] with an explicit payload cap. Servers pass
/// [`MAX_REQUEST_FRAME`] so a hostile header cannot make them allocate
/// response-sized buffers; the claimed length is rejected **before**
/// any allocation, and the caller drops the connection (the stream is
/// unrecoverable mid-frame).
pub fn read_frame_capped(r: &mut impl std::io::Read, cap: usize) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len > cap {
        return Err(Error::protocol(format!(
            "incoming frame too large ({len} bytes, cap {cap})"
        )));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop_check;

    /// One of every request shape — shared by the roundtrip and the
    /// truncation/garbage property tests so new opcodes are covered by
    /// both automatically.
    fn sample_requests() -> Vec<Request> {
        vec![
            Request::Open { path: "data/file.troot".into() },
            Request::Stat { fd: 7 },
            Request::Read { fd: 7, offset: 1 << 40, len: 12345 },
            Request::ReadV { fd: 7, ranges: vec![(0, 10), (100, 20), (1 << 33, 30)] },
            Request::ReadV { fd: 0, ranges: vec![] },
            Request::Close { fd: 7 },
            Request::Put { path: "out.troot".into(), data: vec![1, 2, 3] },
            Request::SubmitQuery { query_json: "{\"input\": \"f\"}".into(), deadline_ms: 0 },
            Request::SubmitQuery { query_json: "x".repeat(100_000), deadline_ms: 30_000 },
            Request::JobStatus { job: u64::MAX },
            Request::FetchResult { job: 12 },
            Request::ListCatalog { spec: "store/*.troot".into() },
            Request::ListCatalog { spec: "catalog:run2018".into() },
            Request::CancelJob { job: 99 },
        ]
    }

    #[test]
    fn request_roundtrip() {
        for r in sample_requests() {
            assert_eq!(Request::decode(&r.encode()).unwrap(), r);
        }
    }

    /// One of every response shape (see [`sample_requests`]).
    fn sample_responses() -> Vec<Response> {
        vec![
            Response::Opened { fd: 1, size: 999 },
            Response::Stats { size: 0 },
            Response::Data { data: vec![0; 100] },
            Response::DataV { chunks: vec![vec![1], vec![], vec![2, 3]] },
            Response::Done,
            Response::Error { msg: "no such file".into() },
            Response::JobAccepted { job: 3 },
            Response::JobState {
                state: 2,
                n_events: 1_000_000,
                n_pass: 777,
                latency_us: 2_500_000,
                cache_hits: 42,
                cache_misses: 7,
                baskets_pruned: 1234,
                baskets_scanned: 56,
                scan_shared: 112,
                batch_id: 5,
                batch_members: 3,
                files_done: 0,
                files_total: 0,
                retries: 2,
                faults_injected: 4,
                backoff_us: 750_000,
                cancelled: 0,
                deadline_exceeded: 0,
                msg: String::new(),
                file_errors: Vec::new(),
                profile: vec![
                    ("MET_pt > 25".into(), 0, 1_000_000, 400_000, 1234),
                    ("trigger(HLT_IsoMu24 | HLT_Mu50)".into(), 3, 400_000, 777, 99),
                ],
            },
            Response::JobState {
                state: 5,
                n_events: 600,
                n_pass: 3,
                latency_us: 1,
                cache_hits: 0,
                cache_misses: 0,
                baskets_pruned: 0,
                baskets_scanned: 9,
                scan_shared: 0,
                batch_id: 0,
                batch_members: 0,
                files_done: 2,
                files_total: 4,
                retries: 0,
                faults_injected: 0,
                backoff_us: 0,
                cancelled: 0,
                deadline_exceeded: 1,
                msg: "deadline exceeded: 5.0s".into(),
                file_errors: vec!["store/bad.troot: truncated".into()],
                profile: Vec::new(),
            },
            Response::Listing { files: vec!["a.troot".into(), "store/b.troot".into()] },
            Response::Listing { files: Vec::new() },
        ]
    }

    #[test]
    fn response_roundtrip() {
        for r in sample_responses() {
            assert_eq!(Response::decode(&r.encode()).unwrap(), r);
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Request::decode(&[]).is_err());
        assert!(Request::decode(&[99]).is_err());
        assert!(Response::decode(&[0]).is_err());
        // trailing bytes
        let mut enc = Request::Stat { fd: 1 }.encode();
        enc.push(0);
        assert!(Request::decode(&enc).is_err());
    }

    #[test]
    fn prop_decode_mutated_never_panics() {
        prop_check("proto-fuzz", 60, |rng| {
            let mut enc = Request::ReadV {
                fd: 3,
                ranges: vec![(10, 20), (30, 40)],
            }
            .encode();
            let i = rng.below(enc.len() as u32) as usize;
            enc[i] ^= 1 << rng.below(8);
            let _ = Request::decode(&enc);
            let mut enc = Response::DataV { chunks: vec![vec![1, 2], vec![3]] }.encode();
            let i = rng.below(enc.len() as u32) as usize;
            enc[i] ^= 1 << rng.below(8);
            let _ = Response::decode(&enc);
        });
    }

    /// Every opcode, every truncation point, plus seeded byte garbage:
    /// decode must return an error or a value — never panic, never
    /// allocate absurdly. (Allocation bombs are separately bounded by
    /// the count caps in decode and [`MAX_REQUEST_FRAME`] at the
    /// framing layer.)
    #[test]
    fn prop_all_opcodes_survive_truncation_and_garbage() {
        for r in sample_requests() {
            let enc = r.encode();
            for cut in 0..enc.len() {
                let _ = Request::decode(&enc[..cut]);
            }
        }
        for r in sample_responses() {
            let enc = r.encode();
            for cut in 0..enc.len() {
                let _ = Response::decode(&enc[..cut]);
            }
        }
        prop_check("proto-fuzz-all-ops", 200, |rng| {
            // Mutate a randomly chosen sample of either direction.
            let reqs = sample_requests();
            let mut enc = reqs[rng.below(reqs.len() as u32) as usize].encode();
            for _ in 0..=rng.below(4) {
                let i = rng.below(enc.len() as u32) as usize;
                enc[i] ^= 1 << rng.below(8);
            }
            let _ = Request::decode(&enc);
            let resps = sample_responses();
            let mut enc = resps[rng.below(resps.len() as u32) as usize].encode();
            for _ in 0..=rng.below(4) {
                let i = rng.below(enc.len() as u32) as usize;
                enc[i] ^= 1 << rng.below(8);
            }
            let _ = Response::decode(&enc);
            // Pure garbage of random length, random opcode byte first.
            let n = rng.below(64) as usize;
            let mut junk = Vec::with_capacity(n + 1);
            junk.push(rng.below(32) as u8);
            for _ in 0..n {
                junk.push(rng.below(256) as u8);
            }
            let _ = Request::decode(&junk);
            let _ = Response::decode(&junk);
        });
    }

    #[test]
    fn request_frame_cap_rejects_oversized_claims() {
        // A header claiming more than MAX_REQUEST_FRAME is rejected by
        // the capped reader servers use, while the general reader (for
        // responses) still accepts it.
        let claimed = (MAX_REQUEST_FRAME + 1) as u32;
        let mut hdr = claimed.to_le_bytes().to_vec();
        hdr.extend_from_slice(&[0; 16]);
        let mut r = hdr.as_slice();
        let err = read_frame_capped(&mut r, MAX_REQUEST_FRAME).unwrap_err();
        assert!(format!("{err}").contains("frame too large"), "{err}");
        // Small frames pass through the capped reader unchanged.
        let mut buf = Vec::new();
        write_frame(&mut buf, b"ok").unwrap();
        let mut r = buf.as_slice();
        assert_eq!(read_frame_capped(&mut r, MAX_REQUEST_FRAME).unwrap(), b"ok");
    }

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        let mut r = buf.as_slice();
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
    }

    #[test]
    fn frame_rejects_oversize() {
        let mut hdr = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        hdr.extend_from_slice(&[0; 16]);
        let mut r = hdr.as_slice();
        assert!(read_frame(&mut r).is_err());
    }
}
