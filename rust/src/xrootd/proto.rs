//! Wire protocol: request/response types and binary framing.
//!
//! Framing: `u32 payload_len (LE) | u8 opcode | fields...`. Strings are
//! `u16 len + bytes`; range vectors are `u32 count + (u64 off, u32 len)*`.

use crate::{Error, Result};

pub const MAX_FRAME: usize = 512 * 1024 * 1024;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// Open a file by (catalog-relative) path.
    Open { path: String },
    /// File size of an open handle.
    Stat { fd: u32 },
    /// Positioned read.
    Read { fd: u32, offset: u64, len: u32 },
    /// Vector read: many ranges, one round-trip.
    ReadV { fd: u32, ranges: Vec<(u64, u32)> },
    Close { fd: u32 },
    /// Upload a file (the DPU ships the filtered output back through
    /// the same protocol).
    Put { path: String, data: Vec<u8> },
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    Opened { fd: u32, size: u64 },
    Stats { size: u64 },
    Data { data: Vec<u8> },
    DataV { chunks: Vec<Vec<u8>> },
    Done,
    Error { msg: String },
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u16).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let s = self
            .buf
            .get(self.pos..self.pos + n)
            .ok_or_else(|| Error::protocol("truncated frame"))?;
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| Error::protocol("invalid utf-8"))
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME {
            return Err(Error::protocol("oversized byte field"));
        }
        Ok(self.take(n)?.to_vec())
    }

    fn finished(&self) -> bool {
        self.pos == self.buf.len()
    }
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Request::Open { path } => {
                out.push(1);
                put_str(&mut out, path);
            }
            Request::Stat { fd } => {
                out.push(2);
                out.extend_from_slice(&fd.to_le_bytes());
            }
            Request::Read { fd, offset, len } => {
                out.push(3);
                out.extend_from_slice(&fd.to_le_bytes());
                out.extend_from_slice(&offset.to_le_bytes());
                out.extend_from_slice(&len.to_le_bytes());
            }
            Request::ReadV { fd, ranges } => {
                out.push(4);
                out.extend_from_slice(&fd.to_le_bytes());
                out.extend_from_slice(&(ranges.len() as u32).to_le_bytes());
                for (o, l) in ranges {
                    out.extend_from_slice(&o.to_le_bytes());
                    out.extend_from_slice(&l.to_le_bytes());
                }
            }
            Request::Close { fd } => {
                out.push(5);
                out.extend_from_slice(&fd.to_le_bytes());
            }
            Request::Put { path, data } => {
                out.push(6);
                put_str(&mut out, path);
                put_bytes(&mut out, data);
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Request> {
        let mut c = Cursor::new(buf);
        let req = match c.u8()? {
            1 => Request::Open { path: c.str()? },
            2 => Request::Stat { fd: c.u32()? },
            3 => Request::Read { fd: c.u32()?, offset: c.u64()?, len: c.u32()? },
            4 => {
                let fd = c.u32()?;
                let n = c.u32()? as usize;
                if n > 4_000_000 {
                    return Err(Error::protocol("too many readv ranges"));
                }
                let mut ranges = Vec::with_capacity(n);
                for _ in 0..n {
                    ranges.push((c.u64()?, c.u32()?));
                }
                Request::ReadV { fd, ranges }
            }
            5 => Request::Close { fd: c.u32()? },
            6 => Request::Put { path: c.str()?, data: c.bytes()? },
            op => return Err(Error::protocol(format!("bad request opcode {op}"))),
        };
        if !c.finished() {
            return Err(Error::protocol("trailing bytes in request"));
        }
        Ok(req)
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Response::Opened { fd, size } => {
                out.push(1);
                out.extend_from_slice(&fd.to_le_bytes());
                out.extend_from_slice(&size.to_le_bytes());
            }
            Response::Stats { size } => {
                out.push(2);
                out.extend_from_slice(&size.to_le_bytes());
            }
            Response::Data { data } => {
                out.push(3);
                put_bytes(&mut out, data);
            }
            Response::DataV { chunks } => {
                out.push(4);
                out.extend_from_slice(&(chunks.len() as u32).to_le_bytes());
                for ch in chunks {
                    put_bytes(&mut out, ch);
                }
            }
            Response::Done => out.push(5),
            Response::Error { msg } => {
                out.push(6);
                put_str(&mut out, msg);
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Response> {
        let mut c = Cursor::new(buf);
        let resp = match c.u8()? {
            1 => Response::Opened { fd: c.u32()?, size: c.u64()? },
            2 => Response::Stats { size: c.u64()? },
            3 => Response::Data { data: c.bytes()? },
            4 => {
                let n = c.u32()? as usize;
                if n > 4_000_000 {
                    return Err(Error::protocol("too many readv chunks"));
                }
                let mut chunks = Vec::with_capacity(n);
                for _ in 0..n {
                    chunks.push(c.bytes()?);
                }
                Response::DataV { chunks }
            }
            5 => Response::Done,
            6 => Response::Error { msg: c.str()? },
            op => return Err(Error::protocol(format!("bad response opcode {op}"))),
        };
        if !c.finished() {
            return Err(Error::protocol("trailing bytes in response"));
        }
        Ok(resp)
    }
}

/// Write one length-prefixed frame to a stream.
pub fn write_frame(w: &mut impl std::io::Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(Error::protocol("frame too large"));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame from a stream.
pub fn read_frame(r: &mut impl std::io::Read) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME {
        return Err(Error::protocol("incoming frame too large"));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop_check;

    #[test]
    fn request_roundtrip() {
        let reqs = [
            Request::Open { path: "data/file.troot".into() },
            Request::Stat { fd: 7 },
            Request::Read { fd: 7, offset: 1 << 40, len: 12345 },
            Request::ReadV { fd: 7, ranges: vec![(0, 10), (100, 20), (1 << 33, 30)] },
            Request::ReadV { fd: 0, ranges: vec![] },
            Request::Close { fd: 7 },
            Request::Put { path: "out.troot".into(), data: vec![1, 2, 3] },
        ];
        for r in reqs {
            assert_eq!(Request::decode(&r.encode()).unwrap(), r);
        }
    }

    #[test]
    fn response_roundtrip() {
        let resps = [
            Response::Opened { fd: 1, size: 999 },
            Response::Stats { size: 0 },
            Response::Data { data: vec![0; 100] },
            Response::DataV { chunks: vec![vec![1], vec![], vec![2, 3]] },
            Response::Done,
            Response::Error { msg: "no such file".into() },
        ];
        for r in resps {
            assert_eq!(Response::decode(&r.encode()).unwrap(), r);
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Request::decode(&[]).is_err());
        assert!(Request::decode(&[99]).is_err());
        assert!(Response::decode(&[0]).is_err());
        // trailing bytes
        let mut enc = Request::Stat { fd: 1 }.encode();
        enc.push(0);
        assert!(Request::decode(&enc).is_err());
    }

    #[test]
    fn prop_decode_mutated_never_panics() {
        prop_check("proto-fuzz", 60, |rng| {
            let mut enc = Request::ReadV {
                fd: 3,
                ranges: vec![(10, 20), (30, 40)],
            }
            .encode();
            let i = rng.below(enc.len() as u32) as usize;
            enc[i] ^= 1 << rng.below(8);
            let _ = Request::decode(&enc);
            let mut enc = Response::DataV { chunks: vec![vec![1, 2], vec![3]] }.encode();
            let i = rng.below(enc.len() as u32) as usize;
            enc[i] ^= 1 << rng.below(8);
            let _ = Response::decode(&enc);
        });
    }

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        let mut r = buf.as_slice();
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
    }

    #[test]
    fn frame_rejects_oversize() {
        let mut hdr = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        hdr.extend_from_slice(&[0; 16]);
        let mut r = hdr.as_slice();
        assert!(read_frame(&mut r).is_err());
    }
}
