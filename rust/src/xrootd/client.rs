//! XRootD client side: the [`Wire`] RPC abstraction, the in-process
//! virtual-time wire, the real TCP wire, and [`RemoteFile`] which makes
//! a remote file usable wherever [`ReadAt`] is expected (the troot
//! reader, TTreeCache, the filtering engine).

use super::proto::{read_frame, write_frame, Request, Response};
use crate::metrics::{Stage, Timeline};
use crate::net::LinkModel;
use crate::troot::ReadAt;
use crate::{Error, Result};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

/// One request/response exchange with the storage server.
pub trait Wire: Send + Sync {
    /// Send one request and wait for its response.
    fn call(&self, req: Request) -> Result<Response>;

    /// Human label for reports.
    fn label(&self) -> String {
        "wire".into()
    }
}

/// In-process wire: requests go straight to an [`super::XrdServer`]
/// handle; transfer time is *charged* to the timeline per the
/// [`LinkModel`] instead of sleeping. Requests and responses are still
/// encoded/decoded so the exact protocol bytes are accounted.
pub struct LoopbackWire {
    server: super::XrdServer,
    link: LinkModel,
    timeline: Timeline,
    /// Stage that transfer time is attributed to (fetch vs open).
    stage: AtomicU8,
}

impl LoopbackWire {
    /// A wire to `server` charging `link` time onto `timeline`.
    pub fn new(server: super::XrdServer, link: LinkModel, timeline: Timeline) -> Self {
        LoopbackWire { server, link, timeline, stage: AtomicU8::new(stage_id(Stage::BasketFetch)) }
    }

    /// Change which stage subsequent transfer time is attributed to.
    pub fn set_stage(&self, stage: Stage) {
        self.stage.store(stage_id(stage), Ordering::Relaxed);
    }

    fn stage(&self) -> Stage {
        stage_from_id(self.stage.load(Ordering::Relaxed))
    }
}

fn stage_id(s: Stage) -> u8 {
    Stage::ALL.iter().position(|&x| x == s).unwrap() as u8
}

fn stage_from_id(id: u8) -> Stage {
    Stage::ALL[id as usize]
}

impl Wire for LoopbackWire {
    fn call(&self, req: Request) -> Result<Response> {
        let stage = self.stage();
        let req_bytes = req.encode();
        // Request travels client → server.
        self.link.charge(&self.timeline, stage, req_bytes.len() as u64);
        let req = Request::decode(&req_bytes)?;
        let resp = self.server.handle(req);
        let resp_bytes = resp.encode();
        // Response travels server → client (payload-dominated).
        self.timeline
            .charge(stage, self.link.exchange_time(resp_bytes.len() as u64) - self.link.rtt_s);
        self.timeline.add_bytes(stage, resp_bytes.len() as u64);
        Response::decode(&resp_bytes)
    }

    fn label(&self) -> String {
        format!("loopback/{}", self.link.label)
    }
}

/// Real TCP wire (integration path). No virtual charging: transfers
/// take real wall time (optionally shaped by
/// [`crate::net::ThrottledStream`] at the socket level).
pub struct TcpWire {
    stream: Mutex<std::net::TcpStream>,
    peer: String,
}

impl TcpWire {
    /// Connect to a server's TCP endpoint.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = std::net::TcpStream::connect(addr)
            .map_err(|e| Error::protocol(format!("connect {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        Ok(TcpWire { stream: Mutex::new(stream), peer: addr.to_string() })
    }
}

impl Wire for TcpWire {
    fn call(&self, req: Request) -> Result<Response> {
        let mut stream = self.stream.lock().unwrap();
        write_frame(&mut *stream, &req.encode())?;
        let frame = read_frame(&mut *stream)?;
        Response::decode(&frame)
    }

    fn label(&self) -> String {
        format!("tcp/{}", self.peer)
    }
}

/// XRootD client: opens files over a wire.
pub struct XrdClient {
    wire: Arc<dyn Wire>,
}

impl XrdClient {
    /// A client speaking over `wire`.
    pub fn new(wire: Arc<dyn Wire>) -> Self {
        XrdClient { wire }
    }

    /// The underlying wire (shared with open files).
    pub fn wire(&self) -> &Arc<dyn Wire> {
        &self.wire
    }

    /// Open a remote file; returns a [`RemoteFile`] usable as
    /// [`ReadAt`].
    pub fn open(&self, path: &str) -> Result<RemoteFile> {
        match self.wire.call(Request::Open { path: path.into() })? {
            Response::Opened { fd, size } => {
                Ok(RemoteFile { wire: self.wire.clone(), fd, size })
            }
            Response::Error { msg } => Err(Error::protocol(msg)),
            other => Err(Error::protocol(format!("unexpected response {other:?}"))),
        }
    }

    /// Upload a file to the server catalog (output shipping).
    pub fn put(&self, path: &str, data: &[u8]) -> Result<()> {
        match self.wire.call(Request::Put { path: path.into(), data: data.to_vec() })? {
            Response::Done => Ok(()),
            Response::Error { msg } => Err(Error::protocol(msg)),
            other => Err(Error::protocol(format!("unexpected response {other:?}"))),
        }
    }

    /// List the files a dataset spec (glob, `catalog:NAME`, single
    /// file) resolves to on the server — how a remote client previews
    /// a dataset before submitting a query over it.
    pub fn list(&self, spec: &str) -> Result<Vec<String>> {
        match self.wire.call(Request::ListCatalog { spec: spec.into() })? {
            Response::Listing { files } => Ok(files),
            Response::Error { msg } => Err(Error::protocol(msg)),
            other => Err(Error::protocol(format!("unexpected response {other:?}"))),
        }
    }
}

/// An open remote file handle.
pub struct RemoteFile {
    wire: Arc<dyn Wire>,
    fd: u32,
    size: u64,
}

impl RemoteFile {
    /// Release the server-side handle.
    pub fn close(&self) -> Result<()> {
        match self.wire.call(Request::Close { fd: self.fd })? {
            Response::Done => Ok(()),
            other => Err(Error::protocol(format!("unexpected response {other:?}"))),
        }
    }
}

impl ReadAt for RemoteFile {
    fn read_at(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
        match self.wire.call(Request::Read { fd: self.fd, offset, len: len as u32 })? {
            Response::Data { data } => {
                if data.len() != len {
                    return Err(Error::protocol("short read"));
                }
                Ok(data)
            }
            Response::Error { msg } => Err(Error::protocol(msg)),
            other => Err(Error::protocol(format!("unexpected response {other:?}"))),
        }
    }

    fn read_vec(&self, ranges: &[(u64, usize)]) -> Result<Vec<Vec<u8>>> {
        let req_ranges: Vec<(u64, u32)> =
            ranges.iter().map(|&(o, l)| (o, l as u32)).collect();
        match self.wire.call(Request::ReadV { fd: self.fd, ranges: req_ranges })? {
            Response::DataV { chunks } => {
                if chunks.len() != ranges.len()
                    || chunks.iter().zip(ranges).any(|(c, &(_, l))| c.len() != l)
                {
                    return Err(Error::protocol("short readv"));
                }
                Ok(chunks)
            }
            Response::Error { msg } => Err(Error::protocol(msg)),
            other => Err(Error::protocol(format!("unexpected response {other:?}"))),
        }
    }

    fn size(&self) -> Result<u64> {
        Ok(self.size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::DiskModel;
    use crate::xrootd::XrdServer;

    fn setup() -> (XrdServer, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("xrd_cli_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("data.bin"), (0u8..=255).collect::<Vec<_>>()).unwrap();
        (XrdServer::new(&dir, DiskModel::ideal()), dir)
    }

    #[test]
    fn loopback_read_and_charge() {
        let (srv, _dir) = setup();
        let tl = Timeline::new();
        let wire = Arc::new(LoopbackWire::new(srv, LinkModel::wan_1g(), tl.clone()));
        let client = XrdClient::new(wire.clone());
        let file = client.open("data.bin").unwrap();
        assert_eq!(file.size().unwrap(), 256);
        assert_eq!(file.read_at(10, 4).unwrap(), vec![10, 11, 12, 13]);
        let v = file.read_vec(&[(0, 2), (254, 2)]).unwrap();
        assert_eq!(v, vec![vec![0, 1], vec![254, 255]]);
        // Three exchanges → at least 3 RTTs charged.
        assert!(tl.stage_total(Stage::BasketFetch) >= 3.0 * 0.030);
        assert_eq!(tl.counter("link_round_trips"), 3);
        file.close().unwrap();
    }

    #[test]
    fn loopback_stage_attribution() {
        let (srv, _dir) = setup();
        let tl = Timeline::new();
        let wire = Arc::new(LoopbackWire::new(srv, LinkModel::wan_1g(), tl.clone()));
        wire.set_stage(Stage::OpenMeta);
        let client = XrdClient::new(wire.clone());
        let f = client.open("data.bin").unwrap();
        assert!(tl.stage_total(Stage::OpenMeta) > 0.0);
        assert_eq!(tl.stage_total(Stage::BasketFetch), 0.0);
        wire.set_stage(Stage::BasketFetch);
        f.read_at(0, 1).unwrap();
        assert!(tl.stage_total(Stage::BasketFetch) > 0.0);
    }

    #[test]
    fn open_missing_file_errors() {
        let (srv, _dir) = setup();
        let tl = Timeline::new();
        let wire = Arc::new(LoopbackWire::new(srv, LinkModel::local(), tl));
        let client = XrdClient::new(wire);
        assert!(client.open("missing.bin").is_err());
    }

    #[test]
    fn tcp_wire_end_to_end() {
        let (srv, _dir) = setup();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let handle = srv.serve_tcp(listener, stop.clone());

        let wire = Arc::new(TcpWire::connect(&addr.to_string()).unwrap());
        let client = XrdClient::new(wire);
        let file = client.open("data.bin").unwrap();
        assert_eq!(file.read_at(100, 3).unwrap(), vec![100, 101, 102]);
        let v = file.read_vec(&[(5, 1), (6, 1)]).unwrap();
        assert_eq!(v, vec![vec![5], vec![6]]);
        client.put("up/loaded.bin", b"xyz").unwrap();
        file.close().unwrap();
        drop(file);
        drop(client);

        crate::xrootd::server::stop_serving(addr, &stop, handle);
    }

    #[test]
    fn remote_file_through_troot_reader() {
        // A troot file served over the loopback wire opens and reads
        // through the normal TRootReader.
        use crate::compress::Codec;
        use crate::troot::{BranchDesc, ColumnData, DType, TRootReader, TRootWriter};
        let dir = std::env::temp_dir().join("xrd_troot");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.troot");
        let mut w = TRootWriter::new(&path, Codec::Lz4, 32);
        w.add_branch(
            BranchDesc::scalar("x", DType::F32),
            ColumnData::scalar_f32((0..100).map(|i| i as f32).collect()),
        )
        .unwrap();
        w.finalize().unwrap();

        let srv = XrdServer::new(&dir, DiskModel::ideal());
        let tl = Timeline::new();
        let wire = Arc::new(LoopbackWire::new(srv, LinkModel::shared_10g(), tl.clone()));
        let client = XrdClient::new(wire);
        let remote = client.open("events.troot").unwrap();
        let reader = TRootReader::open(remote).unwrap();
        assert_eq!(reader.n_events(), 100);
        let col = reader.read_branch_all("x").unwrap();
        assert_eq!(col.n_events(), 100);
        assert!(tl.counter("link_round_trips") > 0);
    }
}
