//! XRootD-like remote data access (§2.2).
//!
//! WLCG storage clusters export ROOT files through XRootD: a compute
//! node's client opens a file on the data-transfer node and issues
//! positioned reads — including **vector reads** (`readv`), which
//! TTreeCache uses to batch many small basket fetches into one
//! round-trip.
//!
//! This module provides:
//!
//! * [`proto`] — the wire protocol: OPEN / STAT / READ / READV / CLOSE
//!   with a compact binary framing;
//! * [`server`] — the storage-side daemon: a file catalog over a
//!   directory, charging [`crate::net::DiskModel`] time for backend
//!   I/O, servable in-process or over TCP;
//! * [`client`] — the client: a [`Wire`](client::Wire) RPC abstraction
//!   with an in-process virtual-time wire ([`client::LoopbackWire`])
//!   and a real TCP wire ([`client::TcpWire`]), plus
//!   [`client::RemoteFile`] implementing [`crate::troot::ReadAt`];
//! * [`cache`] — **TTreeCache**: learns the basket access plan and
//!   prefetches it with large vector reads (100 MB default, as in the
//!   paper's setup). Crucially — and this reproduces the Figure 5a
//!   effect — it only engages on *remote* stores; local reads bypass
//!   it, paying per-basket seeks.

pub mod cache;
pub mod client;
pub mod proto;
pub mod server;

pub use cache::TTreeCache;
pub use client::{LoopbackWire, RemoteFile, TcpWire, Wire, XrdClient};
pub use proto::{Request, Response};
pub use server::XrdServer;

/// Default TTreeCache capacity (paper setup: "A 100 MB TTreeCache is
/// used in all methods").
pub const DEFAULT_CACHE_BYTES: usize = 100 * 1000 * 1000;
