//! XRootD-like storage server: a file catalog rooted at a directory,
//! served in-process (virtual-time benches) or over TCP (integration).
//!
//! Backend reads charge [`DiskModel`] time to the job's [`Timeline`] —
//! the server *is* the data-transfer node whose disk pool the paper's
//! storage cluster reads from. Vector reads are coalesced before the
//! disk model is applied, which is exactly why `readv` from TTreeCache
//! (or the DPU) beats per-basket random reads in Figure 5a.

use super::proto::{read_frame_capped, write_frame, Request, Response, MAX_REQUEST_FRAME};
use crate::metrics::{Stage, Timeline};
use crate::net::DiskModel;
use crate::{Error, Result};
use std::collections::HashMap;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex};

/// Storage server state. `Clone` shares the catalog (Arc inside).
#[derive(Clone)]
pub struct XrdServer {
    inner: Arc<ServerInner>,
}

struct ServerInner {
    root: PathBuf,
    disk: DiskModel,
    /// Virtual-time sink for backend I/O (None on the real-TCP path,
    /// where I/O takes real time).
    timeline: Mutex<Option<Timeline>>,
    next_fd: AtomicU32,
    open: Mutex<HashMap<u32, Arc<std::fs::File>>>,
    /// Bytes served (stat counter for reports).
    pub_served: AtomicU64Wrapper,
}

// Small newtype because AtomicU64 lacks Clone in the struct derive.
struct AtomicU64Wrapper(std::sync::atomic::AtomicU64);

impl XrdServer {
    /// Serve files under `root` with the given backend disk model.
    pub fn new(root: impl Into<PathBuf>, disk: DiskModel) -> Self {
        XrdServer {
            inner: Arc::new(ServerInner {
                root: root.into(),
                disk,
                timeline: Mutex::new(None),
                next_fd: AtomicU32::new(1),
                open: Mutex::new(HashMap::new()),
                pub_served: AtomicU64Wrapper(std::sync::atomic::AtomicU64::new(0)),
            }),
        }
    }

    /// Attach the per-job timeline that backend I/O time is charged to.
    pub fn set_timeline(&self, timeline: Option<Timeline>) {
        *self.inner.timeline.lock().unwrap() = timeline;
    }

    /// The backend disk model this server charges for reads.
    pub fn disk(&self) -> DiskModel {
        self.inner.disk
    }

    /// Total payload bytes served over the server's lifetime (READ and
    /// READV responses). Surfaced in the end-of-job metrics report as
    /// the `xrd_bytes_served` counter.
    pub fn bytes_served(&self) -> u64 {
        self.inner.pub_served.0.load(Ordering::Relaxed)
    }

    fn resolve(&self, path: &str) -> Result<PathBuf> {
        // Reject traversal; catalog paths are relative.
        if path.contains("..") || path.starts_with('/') {
            return Err(Error::protocol(format!("illegal path {path}")));
        }
        Ok(self.inner.root.join(path))
    }

    fn charge_disk(&self, secs: f64) {
        if let Some(tl) = self.inner.timeline.lock().unwrap().as_ref() {
            tl.charge(Stage::BasketFetch, secs);
            tl.count("disk_ops", 1);
        }
    }

    fn file(&self, fd: u32) -> Result<Arc<std::fs::File>> {
        self.inner
            .open
            .lock()
            .unwrap()
            .get(&fd)
            .cloned()
            .ok_or_else(|| Error::protocol(format!("bad fd {fd}")))
    }

    /// Handle one request (the in-process entry point; the TCP loop
    /// calls this too).
    pub fn handle(&self, req: Request) -> Response {
        match self.handle_inner(req) {
            Ok(resp) => resp,
            Err(e) => Response::Error { msg: e.to_string() },
        }
    }

    fn handle_inner(&self, req: Request) -> Result<Response> {
        match req {
            Request::Open { path } => {
                let full = self.resolve(&path)?;
                let file = std::fs::File::open(&full)
                    .map_err(|e| Error::protocol(format!("open {path}: {e}")))?;
                let size = file.metadata()?.len();
                let fd = self.inner.next_fd.fetch_add(1, Ordering::Relaxed);
                self.inner.open.lock().unwrap().insert(fd, Arc::new(file));
                // Opening costs one metadata seek.
                self.charge_disk(self.inner.disk.seek_s);
                Ok(Response::Opened { fd, size })
            }
            Request::Stat { fd } => {
                let size = self.file(fd)?.metadata()?.len();
                Ok(Response::Stats { size })
            }
            Request::Read { fd, offset, len } => {
                let file = self.file(fd)?;
                let mut buf = vec![0u8; len as usize];
                file.read_exact_at(&mut buf, offset)
                    .map_err(|e| Error::protocol(format!("read: {e}")))?;
                self.charge_disk(self.inner.disk.read_time(len as u64));
                self.inner.pub_served.0.fetch_add(len as u64, Ordering::Relaxed);
                Ok(Response::Data { data: buf })
            }
            Request::ReadV { fd, ranges } => {
                let file = self.file(fd)?;
                let mut chunks = Vec::with_capacity(ranges.len());
                let mut total = 0u64;
                for &(offset, len) in &ranges {
                    let mut buf = vec![0u8; len as usize];
                    file.read_exact_at(&mut buf, offset)
                        .map_err(|e| Error::protocol(format!("readv: {e}")))?;
                    total += len as u64;
                    chunks.push(buf);
                }
                let r: Vec<(u64, usize)> =
                    ranges.iter().map(|&(o, l)| (o, l as usize)).collect();
                self.charge_disk(self.inner.disk.readv_time(&r));
                self.inner.pub_served.0.fetch_add(total, Ordering::Relaxed);
                Ok(Response::DataV { chunks })
            }
            Request::Close { fd } => {
                self.inner.open.lock().unwrap().remove(&fd);
                Ok(Response::Done)
            }
            Request::Put { path, data } => {
                let full = self.resolve(&path)?;
                if let Some(parent) = full.parent() {
                    std::fs::create_dir_all(parent)?;
                }
                std::fs::write(&full, &data)?;
                Ok(Response::Done)
            }
            Request::ListCatalog { spec } => {
                // Resolve the dataset spec against the exported root —
                // the same (traversal-validating) resolution the job
                // layers use, so remote clients can preview exactly
                // what a glob or `catalog:NAME` submission will cover.
                let spec = crate::query::DatasetSpec::parse(&spec);
                let files = crate::catalog::resolve(&spec, &self.inner.root)?;
                // Listing costs one metadata seek.
                self.charge_disk(self.inner.disk.seek_s);
                Ok(Response::Listing { files })
            }
            Request::SubmitQuery { .. }
            | Request::JobStatus { .. }
            | Request::FetchResult { .. } => Err(Error::protocol(
                "this endpoint serves files only; submit skim jobs to a \
                 multi-tenant service (`skimroot serve`)",
            )),
        }
    }

    /// Serve TCP connections on `listener` until `stop` goes true.
    /// One thread per connection (the DTN is not the bottleneck here).
    pub fn serve_tcp(
        &self,
        listener: std::net::TcpListener,
        stop: Arc<AtomicBool>,
    ) -> std::thread::JoinHandle<()> {
        let server = self.clone();
        serve_requests_tcp(listener, stop, move |req| server.handle(req))
    }
}

/// Serve the framed request/response protocol over TCP until `stop`
/// goes true, dispatching each decoded [`Request`] to `handle` — the
/// accept loop shared by [`XrdServer::serve_tcp`] (plain file serving)
/// and [`crate::serve::SkimService::serve_tcp`] (file serving + skim
/// job frames). One thread per connection.
pub fn serve_requests_tcp<H>(
    listener: std::net::TcpListener,
    stop: Arc<AtomicBool>,
    handle: H,
) -> std::thread::JoinHandle<()>
where
    H: Fn(Request) -> Response + Send + Sync + Clone + 'static,
{
    std::thread::spawn(move || {
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        // Blocking accept: the thread sleeps in the kernel until a
        // client connects — no poll interval, no added accept latency.
        // Stopping therefore needs a wakeup: use [`stop_serving`]
        // (flag + self-connection) rather than flipping `stop` alone.
        loop {
            let accepted = listener.accept();
            if stop.load(Ordering::SeqCst) {
                break; // `accepted` may be the stop poke — drop it
            }
            // Reap finished connections so a long-lived service does
            // not accumulate one dead JoinHandle per client.
            conns.retain(|c| !c.is_finished());
            match accepted {
                Ok((stream, _)) => {
                    let handle = handle.clone();
                    let stop = stop.clone();
                    conns.push(std::thread::spawn(move || {
                        serve_connection(stream, stop, handle);
                    }));
                }
                // Transient per-connection failures (aborted handshake,
                // fd pressure) must not kill the acceptor.
                Err(e) if e.kind() == std::io::ErrorKind::ConnectionAborted => continue,
                Err(_) => break,
            }
        }
        for c in conns {
            let _ = c.join();
        }
    })
}

/// Stop a [`serve_requests_tcp`] loop and join it: flip the stop flag,
/// then poke the listener with throwaway connections until the accept
/// thread (blocked in the kernel) wakes, observes the flag and exits.
/// The retry loop makes the wakeup robust to a poke racing ahead of
/// the flag store.
pub fn stop_serving(
    addr: impl std::net::ToSocketAddrs,
    stop: &AtomicBool,
    handle: std::thread::JoinHandle<()>,
) {
    stop.store(true, Ordering::SeqCst);
    while !handle.is_finished() {
        let _ = std::net::TcpStream::connect(&addr);
        std::thread::park_timeout(std::time::Duration::from_millis(1));
    }
    let _ = handle.join();
}

fn serve_connection<H>(mut stream: std::net::TcpStream, stop: Arc<AtomicBool>, handle: H)
where
    H: Fn(Request) -> Response,
{
    // Periodic read timeout so idle connections observe `stop` and
    // shutdown joins cleanly even with live clients.
    stream
        .set_read_timeout(Some(std::time::Duration::from_millis(200)))
        .ok();
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let frame = match read_frame_capped(&mut stream, MAX_REQUEST_FRAME) {
            Ok(f) => f,
            Err(crate::Error::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue; // idle: re-check stop
            }
            // Oversized length claim: nothing was allocated, but the
            // stream is desynchronized mid-frame — answer best-effort
            // and drop only this connection; the server keeps serving
            // every other client.
            Err(crate::Error::Protocol(msg)) => {
                let _ = write_frame(&mut stream, &Response::Error { msg }.encode());
                return;
            }
            Err(_) => return, // disconnect
        };
        // A malformed payload inside an intact frame leaves the stream
        // synchronized: reply with the decode error and keep serving.
        let resp = match Request::decode(&frame) {
            Ok(req) => handle(req),
            Err(e) => Response::Error { msg: e.to_string() },
        };
        if write_frame(&mut stream, &resp.encode()).is_err() {
            return;
        }
    }
}

/// Check a path exists under the catalog (helper for tools).
pub fn catalog_has(root: &Path, rel: &str) -> bool {
    root.join(rel).exists()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::xrootd::proto::read_frame;

    fn setup() -> (XrdServer, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("xrd_srv_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("hello.bin"), b"0123456789abcdef").unwrap();
        (XrdServer::new(&dir, DiskModel::ideal()), dir)
    }

    #[test]
    fn open_read_close() {
        let (srv, _dir) = setup();
        let resp = srv.handle(Request::Open { path: "hello.bin".into() });
        let (fd, size) = match resp {
            Response::Opened { fd, size } => (fd, size),
            other => panic!("{other:?}"),
        };
        assert_eq!(size, 16);
        match srv.handle(Request::Read { fd, offset: 10, len: 6 }) {
            Response::Data { data } => assert_eq!(data, b"abcdef"),
            other => panic!("{other:?}"),
        }
        match srv.handle(Request::ReadV { fd, ranges: vec![(0, 2), (14, 2)] }) {
            Response::DataV { chunks } => {
                assert_eq!(chunks, vec![b"01".to_vec(), b"ef".to_vec()])
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(srv.handle(Request::Close { fd }), Response::Done);
        // Reads on a closed fd fail.
        match srv.handle(Request::Read { fd, offset: 0, len: 1 }) {
            Response::Error { .. } => {}
            other => panic!("{other:?}"),
        }
        assert_eq!(srv.bytes_served(), 10);
    }

    #[test]
    fn rejects_traversal_and_missing() {
        let (srv, _dir) = setup();
        for path in ["../etc/passwd", "/etc/passwd", "nope.bin"] {
            match srv.handle(Request::Open { path: path.into() }) {
                Response::Error { .. } => {}
                other => panic!("{path}: {other:?}"),
            }
        }
    }

    #[test]
    fn read_past_eof_is_error() {
        let (srv, _dir) = setup();
        let fd = match srv.handle(Request::Open { path: "hello.bin".into() }) {
            Response::Opened { fd, .. } => fd,
            other => panic!("{other:?}"),
        };
        match srv.handle(Request::Read { fd, offset: 10, len: 100 }) {
            Response::Error { .. } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn disk_time_charged_to_timeline() {
        let dir = std::env::temp_dir().join("xrd_srv_charge");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("f.bin"), vec![0u8; 1 << 20]).unwrap();
        let srv = XrdServer::new(&dir, DiskModel::disk_pool());
        let tl = Timeline::new();
        srv.set_timeline(Some(tl.clone()));
        let fd = match srv.handle(Request::Open { path: "f.bin".into() }) {
            Response::Opened { fd, .. } => fd,
            other => panic!("{other:?}"),
        };
        srv.handle(Request::Read { fd, offset: 0, len: 1 << 20 });
        let t = tl.stage_total(Stage::BasketFetch);
        // open seek + read seek + 1 MiB / 1 GB/s ≈ 5ms + 5ms + 1.05ms
        assert!(t > 0.0105 && t < 0.0125, "t={t}");
    }

    #[test]
    fn put_roundtrip() {
        let (srv, dir) = setup();
        srv.handle(Request::Put { path: "out/result.bin".into(), data: vec![9, 9, 9] });
        assert_eq!(std::fs::read(dir.join("out/result.bin")).unwrap(), vec![9, 9, 9]);
    }

    #[test]
    fn tcp_serving() {
        let (srv, _dir) = setup();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = srv.serve_tcp(listener, stop.clone());

        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        write_frame(&mut stream, &Request::Open { path: "hello.bin".into() }.encode()).unwrap();
        let resp = Response::decode(&read_frame(&mut stream).unwrap()).unwrap();
        let fd = match resp {
            Response::Opened { fd, size } => {
                assert_eq!(size, 16);
                fd
            }
            other => panic!("{other:?}"),
        };
        write_frame(&mut stream, &Request::Read { fd, offset: 0, len: 4 }.encode()).unwrap();
        match Response::decode(&read_frame(&mut stream).unwrap()).unwrap() {
            Response::Data { data } => assert_eq!(data, b"0123"),
            other => panic!("{other:?}"),
        }
        drop(stream);
        stop_serving(addr, &stop, handle);
    }

    #[test]
    fn oversized_frame_drops_one_connection_not_the_server() {
        let (srv, _dir) = setup();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let handle = srv.serve_tcp(listener, stop.clone());

        // A hostile header claiming a 4 GiB request: the server answers
        // with a protocol error and hangs up without allocating.
        use std::io::{Read, Write};
        let mut bad = std::net::TcpStream::connect(addr).unwrap();
        bad.write_all(&u32::MAX.to_le_bytes()).unwrap();
        bad.flush().unwrap();
        let frame = read_frame(&mut bad).unwrap();
        match Response::decode(&frame).unwrap() {
            Response::Error { msg } => assert!(msg.contains("frame too large"), "{msg}"),
            other => panic!("{other:?}"),
        }
        let mut probe = [0u8; 1];
        assert_eq!(bad.read(&mut probe).unwrap(), 0, "connection must be closed");

        // A malformed payload in an intact frame keeps the connection.
        let mut ok = std::net::TcpStream::connect(addr).unwrap();
        write_frame(&mut ok, &[0xEE, 1, 2, 3]).unwrap();
        match Response::decode(&read_frame(&mut ok).unwrap()).unwrap() {
            Response::Error { .. } => {}
            other => panic!("{other:?}"),
        }
        write_frame(&mut ok, &Request::Open { path: "hello.bin".into() }.encode()).unwrap();
        match Response::decode(&read_frame(&mut ok).unwrap()).unwrap() {
            Response::Opened { size, .. } => assert_eq!(size, 16),
            other => panic!("{other:?}"),
        }

        drop(ok);
        stop_serving(addr, &stop, handle);
    }
}
