//! Dataset-native skims: the dataset — not the file — is the unit of
//! work, matching how real HEP reductions iterate catalogs of files.
//!
//! This example generates a 5-file dataset, then:
//!
//! 1. skims it with one glob query (`store/part*.troot`) on the DPU
//!    deployment at fan-out 1 and fan-out 4 — files stripe across the
//!    DPU lanes, and the merged output is **byte-identical** in both;
//! 2. cross-checks the dataset path against a serial single-file
//!    loop: skim each file alone, merge with the shared deterministic
//!    merge ([`skimroot::troot::merge`]) — byte-identical again;
//! 3. corrupts one file and re-runs: the dataset job completes with
//!    the failure isolated to that file (per-file error detail in the
//!    report), instead of failing the whole job.
//!
//! ```sh
//! cargo run --release --example dataset_skim
//! ```

use skimroot::compress::Codec;
use skimroot::coordinator::{Deployment, Placement};
use skimroot::dpu::DpuConfig;
use skimroot::gen::{self, GenConfig};
use skimroot::net::LinkModel;
use skimroot::{DatasetSpec, SkimJob};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = std::env::temp_dir().join("skimroot_dataset_skim");
    let _ = std::fs::remove_dir_all(&dir);
    let storage = dir.join("storage");
    let store = storage.join("store");
    let cfg = GenConfig {
        n_events: 2_000,
        target_branches: 300,
        n_hlt: 60,
        basket_events: 500,
        codec: Codec::Lz4,
        seed: 2018,
    };
    println!("generating 5-file dataset...");
    gen::generate_dataset(&cfg, &store, 5, "run2018")?;

    let query = gen::higgs_query("store/part*.troot", "higgs_ds.troot");

    // 1. One dataset job, DPU placement, fan-out 1 then 4: files
    //    stripe across the lanes; bytes must not depend on fan-out.
    let mut outputs = Vec::new();
    for fan_out in [1usize, 4] {
        let dep = Deployment::builder()
            .name(format!("skimroot-x{fan_out}"))
            .placement(Placement::Dpu(DpuConfig::default()))
            .link(LinkModel::wan_1g())
            .fan_out(fan_out)
            .build()?;
        let report = SkimJob::new(query.clone())
            .storage(&storage)
            .client_dir(dir.join(format!("client_x{fan_out}")))
            .deployment(dep)
            .run()?;
        println!(
            "fan-out {fan_out}: {}/{} files ok, pass {}/{}, latency {}",
            report.files_done(),
            report.files_total(),
            report.result.n_pass,
            report.result.n_events,
            skimroot::util::human_secs(report.latency)
        );
        assert_eq!(report.files_total(), 5);
        assert_eq!(report.files_done(), 5);
        outputs.push(std::fs::read(&report.result.output_path)?);
    }
    assert_eq!(outputs[0], outputs[1], "fan-out must not change the merged bytes");

    // 2. Serial cross-check: skim each file alone, merge the part
    //    outputs in dataset order through the shared merge path.
    let files = skimroot::catalog::resolve(
        &DatasetSpec::parse("store/part*.troot"),
        &storage,
    )?;
    let mut parts = Vec::new();
    for (i, file) in files.iter().enumerate() {
        let single = SkimJob::new(query.for_file(file, format!("serial{i}.troot")))
            .storage(&storage)
            .client_dir(dir.join("client_serial"))
            .deployment(Deployment::skim_root(LinkModel::wan_1g()))
            .run()?;
        parts.push(std::fs::read(&single.result.output_path)?);
    }
    let ref_path = dir.join("serial_merged.troot");
    skimroot::troot::merge::concat_buffers(parts, &ref_path)?;
    assert_eq!(
        outputs[0],
        std::fs::read(&ref_path)?,
        "dataset skim must equal the serial per-file loop, byte for byte"
    );
    println!("dataset output byte-identical to the serial single-file loop");

    // 3. Fault isolation: truncate one file; the job completes with a
    //    per-file failure instead of dying.
    let victim = store.join("part002.troot");
    let bytes = std::fs::read(&victim)?;
    std::fs::write(&victim, &bytes[..bytes.len() / 3])?;
    let mut dep = Deployment::skim_root(LinkModel::wan_1g());
    dep.fault.max_retries = 1;
    let report = SkimJob::new(query.clone())
        .storage(&storage)
        .client_dir(dir.join("client_faulty"))
        .deployment(dep)
        .run()?;
    println!(
        "with one truncated file: {}/{} files ok",
        report.files_done(),
        report.files_total()
    );
    assert_eq!(report.files_done(), 4);
    assert_eq!(report.files_failed(), 1);
    let failed = report.files.iter().find(|f| f.error.is_some()).unwrap();
    println!("  isolated failure: {} -> {}", failed.path, failed.error.as_deref().unwrap());
    assert!(failed.path.ends_with("part002.troot"));
    assert!(report.result.n_pass > 0);

    println!("ok");
    Ok(())
}
