//! Z′ → μμ search skim — a selection the legacy Figure-2c schema
//! **cannot express**, running end-to-end on the open query IR.
//!
//! The cut mixes a trigger OR with a kinematic escape hatch
//! (`HLT_Mu50 || HLT_TkMu100 || max(Muon_pt) > 100`) and sums muon pT
//! over a predicate — both impossible in the old closed schema (whose
//! only disjunction was the trigger list, and whose only aggregation
//! was the hard-wired jet HT). The planner classifies what it can onto
//! the kernel's fixed-function stages and compiles the rest to
//! residual IR expressions; `--explain`-style output below shows the
//! plan honestly falling back from the vectorized kernel path to the
//! interpreter, which evaluates the full IR.
//!
//! ```sh
//! cargo run --release --example zprime_dimuon
//! ```

use skimroot::compress::Codec;
use skimroot::coordinator::{Deployment, Placement};
use skimroot::gen::{self, GenConfig};
use skimroot::net::LinkModel;
use skimroot::query::SkimQuery;
use skimroot::troot::{LocalFile, TRootReader};
use skimroot::SkimJob;

/// TCut-style selection: at least two muons in acceptance, a high-mass
/// proxy on the summed high-pT muon system, and a trigger OR with a
/// high-pT muon escape (events a prescaled trigger would lose).
const CUT: &str = "nMuon >= 2 && count(Muon_pt > 20 && abs(Muon_eta) < 2.4) >= 2 \
                   && sum(Muon_pt[Muon_pt > 20]) > 60 \
                   && (HLT_Mu50 || HLT_TkMu100 || max(Muon_pt) > 60)";

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = std::env::temp_dir().join("skimroot_zprime");
    let storage = dir.join("storage");
    std::fs::create_dir_all(&storage)?;

    // 1. A synthetic NanoAOD-like dataset (full schema shape, small).
    let input = storage.join("events.troot");
    let cfg = GenConfig {
        n_events: 8_000,
        target_branches: 300,
        n_hlt: 60,
        basket_events: 500,
        codec: Codec::Lz4,
        seed: 2507,
    };
    let summary = gen::generate(&cfg, &input)?;
    println!(
        "generated {}: {} events, {} branches",
        input.display(),
        summary.n_events,
        summary.n_branches,
    );

    // 2. The query: fluent builder + cut string (no JSON needed).
    let query = SkimQuery::new("events.troot", "zprime_dimuon.troot")
        .keep(&["Muon_*", "nMuon", "MET_pt", "run", "event", "HLT_Mu50", "HLT_TkMu100"])
        .with_cut_str(CUT)?;
    println!("\ncut string:\n  {CUT}\n");

    // 3. The job. No PJRT runtime is attached, and the plan would
    //    reject the kernel anyway — the explain output shows why.
    let job = SkimJob::new(query)
        .storage(&storage)
        .client_dir(dir.join("client"))
        .deployment(
            Deployment::builder()
                .name("zprime-client")
                .placement(Placement::Client)
                .link(LinkModel::local())
                .use_pjrt(false)
                .build()?,
        );

    // 4. `skimroot skim --explain` equivalent: the compiled plan.
    println!("{}", job.explain()?);

    // 5. Run end-to-end on the interpreter.
    let report = job.run()?;
    assert!(!report.result.vectorized, "IR residuals must fall back to the interpreter");
    println!(
        "skim [{}]: {} / {} events pass ({:.2}%), funnel {:?}",
        report.name,
        report.result.n_pass,
        report.result.n_events,
        100.0 * report.result.n_pass as f64 / report.result.n_events as f64,
        report.result.stage_funnel,
    );

    // 6. The filtered file is a regular troot file with the kept branches.
    let out_path = &report.result.output_path;
    let reader = TRootReader::open(LocalFile::open(out_path)?)?;
    assert_eq!(reader.n_events(), report.result.n_pass);
    println!(
        "output {}: {} events, {} branches",
        out_path.display(),
        reader.n_events(),
        reader.meta().branches.len(),
    );
    Ok(())
}
