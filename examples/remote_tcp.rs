//! Real-socket integration: the same protocol code over genuine TCP.
//!
//! Spins up (all in one process, separate threads):
//! 1. the XRootD-like storage server on a TCP port;
//! 2. the DPU HTTP service (separated-host mode) whose handler fetches
//!    from the storage directory and filters;
//! 3. an HTTP client that POSTs the Higgs JSON query — what the paper
//!    does with `curl` — and saves the returned filtered file.
//!
//! ```sh
//! cargo run --release --example remote_tcp
//! ```

use skimroot::compress::Codec;
use skimroot::coordinator::{Deployment, Placement};
use skimroot::dpu::http::{self, post_skim, DpuHttpServer};
use skimroot::dpu::DpuConfig;
use skimroot::gen::{self, GenConfig};
use skimroot::net::{DiskModel, LinkModel};
use skimroot::troot::{LocalFile, TRootReader};
use skimroot::xrootd::{Request, Response, TcpWire, Wire, XrdServer};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = std::env::temp_dir().join("skimroot_remote_tcp");
    std::fs::create_dir_all(&dir)?;
    let input = dir.join("events.troot");
    if !input.exists() {
        let cfg = GenConfig {
            n_events: 4_000,
            target_branches: 300,
            n_hlt: 60,
            basket_events: 500,
            codec: Codec::Lz4,
            seed: 77,
        };
        gen::generate(&cfg, &input)?;
    }
    println!("dataset ready at {}", input.display());

    // --- storage server over TCP ---------------------------------------
    let storage = XrdServer::new(&dir, DiskModel::ideal());
    let xrd_listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let xrd_addr = xrd_listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let xrd_thread = storage.serve_tcp(xrd_listener, stop.clone());
    println!("xrootd-like server on {xrd_addr}");

    // Sanity: a raw protocol exchange over the socket.
    {
        let wire = TcpWire::connect(&xrd_addr.to_string())?;
        match wire.call(Request::Open { path: "events.troot".into() })? {
            Response::Opened { fd, size } => {
                println!("protocol check: opened fd={fd}, size={size}");
                wire.call(Request::Close { fd })?;
            }
            other => panic!("unexpected: {other:?}"),
        }
    }

    // --- DPU HTTP service ------------------------------------------------
    // The standard separated-host executor: each POST /skim runs a
    // SkimJob with DPU placement against the storage directory (the
    // DPU and DTN share the host over PCIe; ideal disk + local link so
    // the example's timings are the real protocol work).
    let deployment = Deployment::builder()
        .name("dpu-http")
        .placement(Placement::Dpu(DpuConfig::default()))
        .store(DiskModel::ideal())
        .link(LinkModel::local())
        .build()?;
    let dpu_server = DpuHttpServer::new(http::storage_handler(
        dir.clone(),
        dir.join("dpu_work"),
        None,
        deployment,
    ));
    let http_listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let http_addr = http_listener.local_addr()?;
    let http_thread = dpu_server.serve(http_listener, stop.clone());
    println!("DPU HTTP service on {http_addr} (separated-host mode)");

    // --- the user's curl ---------------------------------------------------
    let query = gen::higgs_query("events.troot", "higgs_skim.troot");
    let payload = query.to_json().to_string();
    println!("\nPOST /skim ({} bytes of JSON)...", payload.len());
    let (status, headers, body) = post_skim(&http_addr.to_string(), &payload)?;
    assert_eq!(status, 200, "DPU returned {status}");
    println!(
        "HTTP 200: events={} pass={} dpu-elapsed={}s, body {}",
        headers["x-skim-events"],
        headers["x-skim-pass"],
        headers["x-skim-elapsed-secs"],
        skimroot::util::human_bytes(body.len() as u64),
    );

    // --- verify the filtered file ------------------------------------------
    let out_path = dir.join("received_skim.troot");
    std::fs::write(&out_path, &body)?;
    let reader = TRootReader::open(LocalFile::open(&out_path)?)?;
    println!(
        "filtered file verifies: {} events × {} branches",
        reader.n_events(),
        reader.meta().branches.len()
    );

    // One stop flag drives both accept loops; each needs its own poke.
    skimroot::xrootd::server::stop_serving(xrd_addr, &stop, xrd_thread);
    skimroot::xrootd::server::stop_serving(http_addr, &stop, http_thread);
    println!("\nremote_tcp OK");
    Ok(())
}
