//! Multi-DPU fan-out: the first deployment beyond the paper's testbed,
//! unlocked by the open `Deployment` builder.
//!
//! N DPU nodes share one storage server; the job's event range is
//! split cluster-aligned across them, each shard skims through its own
//! engine (own PCIe wire, own TTreeCache, hardware decompression), and
//! the filtered shard files are merged into one output. The selection
//! is identical to the single-DPU run by construction — this example
//! asserts it.
//!
//! ```sh
//! cargo run --release --example multi_dpu
//! SKIM_FAN_OUT=8 cargo run --release --example multi_dpu
//! ```

use skimroot::compress::Codec;
use skimroot::coordinator::{Deployment, Placement};
use skimroot::dpu::DpuConfig;
use skimroot::gen::{self, GenConfig};
use skimroot::net::LinkModel;
use skimroot::SkimJob;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let fan_out: usize = std::env::var("SKIM_FAN_OUT")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);

    let dir = std::env::temp_dir().join("skimroot_multi_dpu");
    let storage = dir.join("storage");
    std::fs::create_dir_all(&storage)?;
    let input = storage.join("events.troot");
    if !input.exists() {
        let cfg = GenConfig {
            n_events: 20_000,
            target_branches: 400,
            n_hlt: 80,
            basket_events: 1000,
            codec: Codec::Lz4,
            seed: 404,
        };
        println!("generating dataset...");
        gen::generate(&cfg, &input)?;
    }
    let query = gen::higgs_query("events.troot", "higgs_skim.troot");

    // The paper's single-DPU method — a preset over the builder.
    let single = SkimJob::new(query.clone())
        .storage(&storage)
        .client_dir(dir.join("client_single"))
        .deployment(Deployment::skim_root(LinkModel::wan_1g()))
        .run()?;
    println!(
        "single DPU   [{}]: pass {}/{}, latency {}",
        single.name,
        single.result.n_pass,
        single.result.n_events,
        skimroot::util::human_secs(single.latency)
    );

    // The same job fanned out across N DPU shards.
    let deployment = Deployment::builder()
        .name(format!("skimroot-x{fan_out}"))
        .placement(Placement::Dpu(DpuConfig::default()))
        .link(LinkModel::wan_1g())
        .fan_out(fan_out)
        .build()?;
    let fanned = SkimJob::new(query)
        .storage(&storage)
        .client_dir(dir.join("client_fanout"))
        .deployment(deployment)
        .run()?;
    println!(
        "{:<12} [{}]: pass {}/{}, latency {}, shards {}",
        "multi DPU",
        fanned.name,
        fanned.result.n_pass,
        fanned.result.n_events,
        skimroot::util::human_secs(fanned.latency),
        fanned.timeline.counter("dpu_shards"),
    );

    assert_eq!(
        fanned.result.n_pass, single.result.n_pass,
        "fan-out must not change the selection"
    );
    assert_eq!(fanned.result.stage_funnel, single.result.stage_funnel);

    println!("\nfan-out stage breakdown:\n{}", fanned.timeline.report());
    println!("\nmulti_dpu OK: {fan_out} shards agree with the single-DPU selection");
    Ok(())
}
