//! **End-to-end driver** (DESIGN.md §End-to-end driver): the paper's
//! evaluation workload on a real (synthetic) dataset through all four
//! deployment modes, reproducing every figure of §4 and reporting the
//! headline speedup.
//!
//! * dataset: NanoAOD-like, 1749 branches (677 `HLT_*` flags), LZ4 and
//!   LZMA-class variants;
//! * query: UCSD-Higgs-style skim — 27 filtering-criteria branches, 89
//!   output branches, preselection → object cuts → HT + trigger OR;
//! * methods: client-side legacy (LZMA & LZ4), client-optimized,
//!   server-side, SkimROOT (DPU).
//!
//! ```sh
//! cargo run --release --example higgs_skim            # standard scale
//! SKIM_SCALE=small cargo run --release --example higgs_skim
//! ```
//!
//! Results are recorded in EXPERIMENTS.md.

use skimroot::coordinator::eval::{self, EvalScale};
use skimroot::runtime::SkimRuntime;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let scale = match std::env::var("SKIM_SCALE").as_deref() {
        Ok("small") => EvalScale::small(),
        _ => EvalScale::standard(),
    };
    let dir = std::env::var("SKIM_DIR").unwrap_or_else(|_| {
        std::env::temp_dir().join("skimroot_higgs").to_string_lossy().into_owned()
    });

    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let runtime = match SkimRuntime::load(&artifacts) {
        Ok(rt) => {
            println!("PJRT runtime loaded ({} variants)", rt.variants().count());
            Some(rt)
        }
        Err(e) => {
            println!("[warn] artifacts unavailable ({e}); interpreter path only");
            None
        }
    };

    println!(
        "dataset: {} events × {} branches under {dir}\n",
        scale.n_events, scale.target_branches
    );
    let env = eval::prepare(&dir, scale)?;
    println!(
        "bandwidth scale: {:.4} (our LZ4 file / paper's 5 GB)\n",
        env.bw_scale
    );
    let report = eval::all_figures(&env, runtime.as_ref())?;
    println!("{report}");
    Ok(())
}
