//! Quickstart: generate a small synthetic NanoAOD-like dataset, write
//! a JSON selection, run a skim through the [`SkimJob`] facade, and
//! plug a custom [`FilterStage`] into the engine pipeline.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use skimroot::compress::Codec;
use skimroot::coordinator::{Deployment, Placement};
use skimroot::engine::{FilterStage, Hook, StageCtx, Verdict};
use skimroot::gen::{self, GenConfig};
use skimroot::net::LinkModel;
use skimroot::query::SkimQuery;
use skimroot::troot::{LocalFile, TRootReader};
use skimroot::SkimJob;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// A custom pipeline stage: per-branch accounting of decompressed
/// bytes, hooked after the built-in `decompress` stage. No engine fork
/// needed — it reads the in-flight group state and always continues.
struct ByteAudit {
    bytes: Mutex<BTreeMap<String, u64>>,
}

impl FilterStage for ByteAudit {
    fn name(&self) -> &str {
        "byte-audit"
    }

    fn run(&self, ctx: &mut StageCtx) -> skimroot::Result<Verdict> {
        if let Some(group) = &ctx.group {
            let mut tab = self.bytes.lock().unwrap();
            // Per-cluster rows are Vecs in phase-1 slot order; resolve
            // slot → branch name through the interned fetch set.
            for cluster in &group.raw {
                for (bm, (raw, _)) in ctx.phase1_branches().iter().zip(cluster) {
                    *tab.entry(bm.desc.name.clone()).or_insert(0) += raw.len() as u64;
                }
            }
        }
        Ok(Verdict::Continue)
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = std::env::temp_dir().join("skimroot_quickstart");
    let storage = dir.join("storage");
    std::fs::create_dir_all(&storage)?;

    // 1. Generate a dataset: 5k events, full schema shape scaled down.
    let input = storage.join("events.troot");
    let cfg = GenConfig {
        n_events: 5_000,
        target_branches: 300,
        n_hlt: 60,
        basket_events: 500,
        codec: Codec::Lz4,
        seed: 2024,
    };
    let summary = gen::generate(&cfg, &input)?;
    println!(
        "generated {}: {} events, {} branches, {} on disk (ratio {:.2})",
        input.display(),
        summary.n_events,
        summary.n_branches,
        skimroot::util::human_bytes(summary.file_bytes),
        summary.compression_ratio()
    );

    // 2. A JSON query — exactly what a user would POST to the DPU.
    let query_json = r#"{
        "input": "events.troot",
        "output": "muon_skim.troot",
        "branches": ["Muon_*", "MET_pt", "nMuon", "run", "event", "HLT_*"],
        "selection": {
            "preselection": [ {"branch": "nMuon", "op": ">=", "value": 1} ],
            "objects": [
                { "collection": "Muon", "min_count": 1, "cuts": [
                    {"var": "Muon_pt",  "op": ">",   "value": 20.0},
                    {"var": "Muon_eta", "op": "|<|", "value": 2.4} ] }
            ],
            "event": { "triggers_any": ["HLT_IsoMu24", "HLT_Mu50"] }
        }
    }"#;
    let query = SkimQuery::from_json_text(query_json)?;

    // 3. A deployment from the open builder: client placement over a
    //    free local link (pass a loaded runtime + drop `use_pjrt(false)`
    //    for the vectorized kernel; the interpreter needs no artifacts).
    let deployment = Deployment::builder()
        .name("quickstart-client")
        .placement(Placement::Client)
        .link(LinkModel::local())
        .use_pjrt(false)
        .build()?;

    // 4. Run through the SkimJob facade with the custom stage plugged
    //    in after the built-in `decompress` stage.
    let audit = Arc::new(ByteAudit { bytes: Mutex::new(BTreeMap::new()) });
    let report = SkimJob::new(query)
        .storage(&storage)
        .client_dir(dir.join("client"))
        .deployment(deployment)
        .stage(Hook::Group, &["decompress"], audit.clone())
        .run()?;

    println!(
        "\nskim [{}]: {} / {} events pass ({:.2}%)",
        report.name,
        report.result.n_pass,
        report.result.n_events,
        100.0 * report.result.n_pass as f64 / report.result.n_events as f64
    );
    println!(
        "selection funnel (preselection → objects → HT → trigger): {:?}",
        report.result.stage_funnel
    );
    for w in &report.result.warnings {
        println!("[warn] {w}");
    }
    println!("\nstage breakdown:\n{}", report.timeline.report());

    // 5. What the custom stage observed: decompressed bytes per branch.
    let tab = audit.bytes.lock().unwrap();
    let mut rows: Vec<(&String, &u64)> = tab.iter().collect();
    rows.sort_by(|a, b| b.1.cmp(a.1));
    println!("\nbyte-audit stage — top criteria branches by decompressed bytes:");
    for (branch, bytes) in rows.iter().take(5) {
        println!("  {:<24} {}", branch, skimroot::util::human_bytes(**bytes));
    }

    // 6. The output is a regular troot file.
    let out_path = &report.result.output_path;
    let reader = TRootReader::open(LocalFile::open(out_path)?)?;
    println!(
        "\noutput {}: {} events, {} branches, {}",
        out_path.display(),
        reader.n_events(),
        reader.meta().branches.len(),
        skimroot::util::human_bytes(report.result.output_bytes)
    );
    Ok(())
}
