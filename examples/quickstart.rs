//! Quickstart: generate a small synthetic NanoAOD-like dataset, write
//! a JSON selection, run a skim locally, and inspect the result.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use skimroot::compress::Codec;
use skimroot::engine::{EngineOpts, SkimEngine};
use skimroot::gen::{self, GenConfig};
use skimroot::metrics::Timeline;
use skimroot::query::SkimQuery;
use skimroot::troot::{LocalFile, ReadAt, TRootReader};
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = std::env::temp_dir().join("skimroot_quickstart");
    std::fs::create_dir_all(&dir)?;

    // 1. Generate a dataset: 5k events, full schema shape scaled down.
    let input = dir.join("events.troot");
    let cfg = GenConfig {
        n_events: 5_000,
        target_branches: 300,
        n_hlt: 60,
        basket_events: 500,
        codec: Codec::Lz4,
        seed: 2024,
    };
    let summary = gen::generate(&cfg, &input)?;
    println!(
        "generated {}: {} events, {} branches, {} on disk (ratio {:.2})",
        input.display(),
        summary.n_events,
        summary.n_branches,
        skimroot::util::human_bytes(summary.file_bytes),
        summary.compression_ratio()
    );

    // 2. A JSON query — exactly what a user would POST to the DPU.
    let query_json = r#"{
        "input": "events.troot",
        "output": "muon_skim.troot",
        "branches": ["Muon_*", "MET_pt", "nMuon", "run", "event", "HLT_*"],
        "selection": {
            "preselection": [ {"branch": "nMuon", "op": ">=", "value": 1} ],
            "objects": [
                { "collection": "Muon", "min_count": 1, "cuts": [
                    {"var": "Muon_pt",  "op": ">",   "value": 20.0},
                    {"var": "Muon_eta", "op": "|<|", "value": 2.4} ] }
            ],
            "event": { "triggers_any": ["HLT_IsoMu24", "HLT_Mu50"] }
        }
    }"#;
    let query = SkimQuery::from_json_text(query_json)?;

    // 3. Run the two-phase engine (interpreter path: no artifacts
    //    needed; pass a loaded SkimRuntime for the vectorized kernel).
    let timeline = Timeline::new();
    let engine = SkimEngine::new(None);
    let opts = EngineOpts { use_pjrt: false, ..Default::default() };
    let store: Arc<dyn ReadAt> = Arc::new(LocalFile::open(&input)?);
    let out_path = dir.join("muon_skim.troot");
    let result = engine.run(store, &query, &timeline, &opts, &out_path)?;

    println!(
        "\nskim: {} / {} events pass ({:.2}%)",
        result.n_pass,
        result.n_events,
        100.0 * result.n_pass as f64 / result.n_events as f64
    );
    println!(
        "selection funnel (preselection → objects → HT → trigger): {:?}",
        result.stage_funnel
    );
    for w in &result.warnings {
        println!("[warn] {w}");
    }
    println!("\nstage breakdown:\n{}", timeline.report());

    // 4. The output is a regular troot file.
    let reader = TRootReader::open(LocalFile::open(&out_path)?)?;
    println!(
        "\noutput {}: {} events, {} branches, {}",
        out_path.display(),
        reader.n_events(),
        reader.meta().branches.len(),
        skimroot::util::human_bytes(result.output_bytes)
    );
    Ok(())
}
