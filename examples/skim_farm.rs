//! A skim *farm*: N concurrent analysis clients firing distinct cuts
//! at one long-lived multi-tenant skim service — the serving-layer
//! scenario ("many users, one hot dataset") beyond the paper's
//! one-query testbed.
//!
//! What this demonstrates (and asserts):
//!
//! * the service schedules the concurrent jobs through its bounded
//!   worker pool and every client gets its filtered file back over
//!   real TCP (`SubmitQuery` / `JobStatus` / `FetchResult` frames);
//! * each output is **byte-identical** to running the same query
//!   serially without the service — multi-tenancy changes throughput,
//!   never results;
//! * the shared decompressed-basket cache reports a **nonzero hit
//!   rate**: the clients' cuts overlap on the hot criteria branches,
//!   so the service decompresses each shared basket once instead of
//!   once per job;
//! * with a batching window enabled (`ServeConfig::batch_window_ms`),
//!   the concurrent same-file jobs merge into **shared-scan batches**:
//!   a **nonzero shared-scan rate** shows members received decoded
//!   baskets from one union pass instead of fetching them themselves.
//!
//! ```sh
//! cargo run --release --example skim_farm
//! SKIM_FARM_N=8 cargo run --release --example skim_farm
//! ```

use skimroot::compress::Codec;
use skimroot::gen::{self, GenConfig};
use skimroot::serve::{ServeConfig, SkimService, SkimServiceClient};
use skimroot::SkimJob;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let n_clients: usize = std::env::var("SKIM_FARM_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
        .max(4);

    let dir = std::env::temp_dir().join("skimroot_skim_farm");
    let storage = dir.join("storage");
    std::fs::create_dir_all(&storage)?;
    let input = storage.join("events.troot");
    if !input.exists() {
        let cfg = GenConfig {
            n_events: 12_000,
            target_branches: 300,
            n_hlt: 60,
            basket_events: 1000,
            codec: Codec::Lz4,
            seed: 777,
        };
        println!("generating dataset...");
        gen::generate(&cfg, &input)?;
    }

    // One long-lived service over the storage catalog.
    let mut cfg = ServeConfig::new(&storage);
    cfg.workers = n_clients.min(8);
    cfg.work_dir = dir.join("serve_work");
    // Batch same-file jobs arriving within the window into one shared
    // scan (generous window: every concurrent submit must land in it).
    cfg.batch_window_ms = 250;
    let deployment = cfg.deployment.clone();
    let service = SkimService::new(cfg)?;
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let handle = service.serve_tcp(listener, stop.clone());
    println!("skim service on {addr}, {n_clients} concurrent clients\n");

    // Distinct per-client cuts, all overlapping on the hot kinematic
    // branches — the sharing the basket cache exists to exploit.
    let cuts = [
        "MET_pt > 20",
        "MET_pt > 40 && nJet >= 2",
        "max(Muon_pt) > 25 || MET_pt > 60",
        "ht(30) > 150",
        "nMuon >= 1 && MET_pt > 10",
        "sum(Jet_pt[Jet_pt > 20]) > 100",
        "count(Jet_pt > 35) >= 2",
        "abs(PV_z) < 10 && MET_pt > 15",
    ];
    let keep = ["MET_pt", "nJet", "Jet_pt", "Muon_pt", "nMuon", "PV_z"];
    let query_for = |i: usize| {
        skimroot::SkimQuery::new("events.troot", format!("farm{i}.troot"))
            .keep(&keep)
            .with_cut_str(cuts[i % cuts.len()])
            .expect("valid cut")
    };

    // Fire all clients concurrently against the one server.
    let results: Vec<(usize, u64, u64, Vec<u8>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_clients)
            .map(|i| {
                let addr = addr.clone();
                let query = query_for(i);
                scope.spawn(move || {
                    let client = SkimServiceClient::connect(&addr).expect("connect");
                    let job = client.submit(&query).expect("submit");
                    let (status, bytes) = client.wait_result(job).expect("job result");
                    println!(
                        "client {i}: job {job} pass {}/{} (cache {} hits / {} misses, \
                         batch {}x{}, scan_shared {}) [{}]",
                        status.n_pass,
                        status.n_events,
                        status.cache_hits,
                        status.cache_misses,
                        status.batch_id,
                        status.batch_members,
                        status.scan_shared,
                        cuts[i % cuts.len()],
                    );
                    (i, status.n_pass, status.scan_shared, bytes)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    // Serial reference: the same queries, one-shot, no service, no
    // shared cache. Outputs must be byte-identical.
    for (i, n_pass, _, served_bytes) in &results {
        let report = SkimJob::new(query_for(*i))
            .storage(&storage)
            .client_dir(dir.join(format!("serial{i}")))
            .deployment(deployment.clone())
            .run()?;
        assert_eq!(report.result.n_pass, *n_pass, "client {i}: pass count diverged");
        let serial_bytes = std::fs::read(&report.result.output_path)?;
        assert_eq!(
            &serial_bytes, served_bytes,
            "client {i}: served output differs from serial run"
        );
    }

    let stats = service.scheduler().cache_stats();
    println!(
        "\nshared basket cache: {} hits / {} misses ({:.0}% hit rate), \
         {} resident, {} evictions",
        stats.hits,
        stats.misses,
        stats.hit_rate() * 100.0,
        skimroot::util::human_bytes(stats.resident_bytes),
        stats.evictions,
    );
    assert!(results.len() >= 4, "farm must run at least 4 concurrent jobs");
    assert!(
        stats.hits > 0,
        "overlapping cuts must share decompressed baskets"
    );
    let scan_shared: u64 = results.iter().map(|(_, _, s, _)| s).sum();
    println!("shared-scan rate: {scan_shared} basket views served by batch scans");
    assert!(
        scan_shared > 0,
        "concurrent same-file jobs must batch into shared scans"
    );

    skimroot::xrootd::server::stop_serving(addr.as_str(), &stop, handle);
    service.shutdown();
    println!("\nskim_farm OK: {n_clients} concurrent jobs, byte-identical to serial runs");
    Ok(())
}
