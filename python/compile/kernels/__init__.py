"""Layer-1 Pallas kernels: the vectorized cut-evaluation hot-spot.

`skim` holds the Pallas implementation; `ref` is the pure-jnp oracle the
kernel is validated against at build time (pytest + hypothesis).
"""

from . import ref, skim  # noqa: F401
