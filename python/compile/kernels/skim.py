"""Layer-1 Pallas kernel: vectorized multi-stage cut evaluation.

The paper's hot spot — per-event selection over columnar physics data —
is a branchy per-event C++ loop on the DPU's ARM cores. On the TPU
stack it becomes a branch-free, padded, batched evaluator (see
DESIGN.md §Hardware-Adaptation): events are tiled over the batch
dimension, object collections are padded to ``M`` slots with a validity
count, and every cut is a masked element-wise compare + per-event
reduction — pure VPU work.

The kernel evaluates a *cut program* (compiled by the Rust planner in
``rust/src/query/plan.rs``; capacities and op codes must stay in sync):

* ``K_OBJ`` object-cut slots ``(enabled, col, op, abs, value)``,
* ``G`` group slots ``(enabled, cut_lo, cut_hi, min_count)`` — an event
  passes a group if ≥ ``min_count`` objects satisfy **all** cuts in
  ``[cut_lo, cut_hi)``,
* ``K_SC`` scalar-cut slots (preselection),
* one HT slot ``(enabled, col, pt_min, ht_min)``,
* a trigger-OR membership vector over the scalar columns.

Op codes: ``0 '>' · 1 '>=' · 2 '<' · 3 '<=' · 4 '==' · 5 '!='``; the
``abs`` flag compares ``|x|``.

Everything is f32; masks are 0.0/1.0. The kernel returns the final
event mask plus the four per-stage masks (preselection, object-level,
HT, trigger) used for staged accounting.

Pallas runs with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed kernel capacities — keep in sync with rust/src/query/plan.rs.
C = 12       # object (jagged) columns
S = 16       # scalar columns
K_OBJ = 12   # object-cut slots
K_SC = 6     # scalar-cut slots
G = 4        # object-group slots

# Default batch tile (events per grid step; TPU target — CPU artifacts
# lower at tile == B, see aot.py). 12·256·16 f32 ≈ 196 KiB of column
# data per tile — comfortably VMEM-resident with double buffering.
TILE_B = 256

N_STAGES = 4  # preselection, object, ht, trigger


def _cmp(x, op, value, abs_flag):
    """Branch-free comparison dispatch on a traced op code."""
    x = jnp.where(abs_flag > 0.5, jnp.abs(x), x)
    res = [x > value, x >= value, x < value, x <= value, x == value, x != value]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for code, r in enumerate(res):
        out = out + jnp.where(op == code, r.astype(jnp.float32), 0.0)
    return jnp.minimum(out, 1.0)


def _gather_row(arr, idx):
    """arr: [C, ...]; idx: traced scalar → arr[idx] as one dynamic
    gather (a single XLA op — far cheaper than a one-hot select fold,
    which costs C full-array passes per cut)."""
    i = jnp.clip(idx.astype(jnp.int32), 0, arr.shape[0] - 1)
    return jax.lax.dynamic_index_in_dim(arr, i, axis=0, keepdims=False)


def _gather_col(cols, col_idx):
    return _gather_row(cols, col_idx)


def _gather_scalar(scalars, col_idx):
    return _gather_row(scalars, col_idx)


def _gather_nobj(nobj, col_idx):
    return _gather_row(nobj, col_idx)


def _evaluate(cols, nobj, scalars, obj_cuts, groups, scalar_cuts, ht, trig):
    """Shared evaluation body (jnp ops only — used inside the Pallas
    kernel on Refs' loaded values and directly by tests)."""
    b = cols.shape[1]
    m = cols.shape[2]
    iota_m = jnp.arange(m, dtype=jnp.float32)[None, :]  # [1, M]

    # --- stage 1: preselection (scalar cuts, ANDed) --------------------
    pre = jnp.ones((b,), dtype=jnp.float32)
    for k in range(K_SC):
        enabled, col, op, abs_flag, value = (scalar_cuts[k, i] for i in range(5))
        x = _gather_scalar(scalars, col)  # [B]
        passed = _cmp(x, op, value, abs_flag)
        pre = pre * jnp.where(enabled > 0.5, passed, 1.0)

    # --- per-cut object pass masks [K_OBJ, B, M] ------------------------
    # (Group membership is the only gate on object-cut slots; the
    # per-slot `enabled` field is reserved/ignored, matching ref.py and
    # the Rust planner.)
    cut_pass = []
    for k in range(K_OBJ):
        _enabled, col, op, abs_flag, value = (obj_cuts[k, i] for i in range(5))
        x = _gather_col(cols, col)              # [B, M]
        valid = (iota_m < _gather_nobj(nobj, col)[:, None]).astype(jnp.float32)
        cut_pass.append(_cmp(x, op, value, abs_flag) * valid)

    # --- stage 2: object-level groups -----------------------------------
    obj = jnp.ones((b,), dtype=jnp.float32)
    for g in range(G):
        enabled, lo, hi, min_count = (groups[g, i] for i in range(4))
        # AND of member cuts per object slot; non-members are neutral.
        acc = jnp.ones((b, m), dtype=jnp.float32)
        any_member = jnp.zeros((b, m), dtype=jnp.float32)
        for k in range(K_OBJ):
            member = jnp.logical_and(k >= lo, k < hi).astype(jnp.float32)
            acc = acc * jnp.where(member > 0.5, cut_pass[k], 1.0)
            any_member = jnp.maximum(any_member, member * jnp.ones((b, m)))
        # Only slots covered by ≥1 member cut count as objects (the
        # member cuts already embed validity).
        count = jnp.sum(acc * any_member, axis=1)  # [B]
        passed = (count >= min_count).astype(jnp.float32)
        obj = obj * jnp.where(enabled > 0.5, passed, 1.0)

    # --- stage 3: HT -----------------------------------------------------
    ht_enabled, ht_col, pt_min, ht_min = (ht[i] for i in range(4))
    jet = _gather_col(cols, ht_col)  # [B, M]
    jet_valid = (iota_m < _gather_nobj(nobj, ht_col)[:, None]).astype(jnp.float32)
    contrib = jnp.where(jet > pt_min, jet, 0.0) * jet_valid
    ht_sum = jnp.sum(contrib, axis=1)
    ht_mask = jnp.where(ht_enabled > 0.5, (ht_sum >= ht_min).astype(jnp.float32), 1.0)

    # --- stage 4: trigger OR ---------------------------------------------
    trig_enabled = trig[0]
    any_fired = jnp.zeros((b,), dtype=jnp.float32)
    for s in range(S):
        member = trig[1 + s]
        fired = (scalars[s] > 0.5).astype(jnp.float32)
        any_fired = jnp.maximum(any_fired, member * fired)
    trig_mask = jnp.where(trig_enabled > 0.5, any_fired, 1.0)

    final = pre * obj * ht_mask * trig_mask
    stages = jnp.stack([pre, obj, ht_mask, trig_mask], axis=0)  # [4, B]
    return final, stages


def _kernel(cols_ref, nobj_ref, scalars_ref, obj_cuts_ref, groups_ref,
            scalar_cuts_ref, ht_ref, trig_ref, out_ref, stages_ref):
    final, stages = _evaluate(
        cols_ref[...], nobj_ref[...], scalars_ref[...], obj_cuts_ref[...],
        groups_ref[...], scalar_cuts_ref[...], ht_ref[...], trig_ref[...],
    )
    out_ref[...] = final
    stages_ref[...] = stages


@functools.partial(jax.jit, static_argnames=("tile_b",))
def skim_mask(cols, nobj, scalars, obj_cuts, groups, scalar_cuts, ht, trig,
              *, tile_b=TILE_B):
    """Evaluate the cut program over a padded batch.

    Args:
      cols:        f32[C, B, M] padded object columns.
      nobj:        f32[C, B] per-column object counts.
      scalars:     f32[S, B] scalar columns.
      obj_cuts:    f32[K_OBJ, 5] (enabled, col, op, abs, value).
      groups:      f32[G, 4] (enabled, cut_lo, cut_hi, min_count).
      scalar_cuts: f32[K_SC, 5] (enabled, col, op, abs, value).
      ht:          f32[4] (enabled, col, pt_min, ht_min).
      trig:        f32[1 + S] (enabled, member per scalar column).

    Returns:
      (mask f32[B], stages f32[4, B]).
    """
    c, b, m = cols.shape
    assert c == C, f"expected {C} object columns, got {c}"
    assert scalars.shape == (S, b)
    tile = min(tile_b, b)
    assert b % tile == 0, f"batch {b} not divisible by tile {tile}"
    grid = (b // tile,)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, tile, m), lambda i: (0, i, 0)),
            pl.BlockSpec((C, tile), lambda i: (0, i)),
            pl.BlockSpec((S, tile), lambda i: (0, i)),
            pl.BlockSpec((K_OBJ, 5), lambda i: (0, 0)),
            pl.BlockSpec((G, 4), lambda i: (0, 0)),
            pl.BlockSpec((K_SC, 5), lambda i: (0, 0)),
            pl.BlockSpec((4,), lambda i: (0,)),
            pl.BlockSpec((1 + S,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((N_STAGES, tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((N_STAGES, b), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(cols, nobj, scalars, obj_cuts, groups, scalar_cuts, ht, trig)


def empty_params():
    """All-disabled parameter bank (accept-everything program)."""
    return dict(
        obj_cuts=jnp.zeros((K_OBJ, 5), jnp.float32),
        groups=jnp.zeros((G, 4), jnp.float32),
        scalar_cuts=jnp.zeros((K_SC, 5), jnp.float32),
        ht=jnp.zeros((4,), jnp.float32),
        trig=jnp.zeros((1 + S,), jnp.float32),
    )
