"""Pure-numpy oracle for the skim kernel.

Deliberately written event-by-event with Python control flow (the way a
physicist's ROOT macro reads) rather than vectorized — an independent
implementation the Pallas kernel is checked against. Slow, but tests
use small batches.
"""

import numpy as np

from . import skim


def _cmp(x, op, value, abs_flag):
    if abs_flag > 0.5:
        x = abs(x)
    op = int(round(op))
    if op == 0:
        return x > value
    if op == 1:
        return x >= value
    if op == 2:
        return x < value
    if op == 3:
        return x <= value
    if op == 4:
        return x == value
    if op == 5:
        return x != value
    raise ValueError(f"bad op code {op}")


def skim_mask_ref(cols, nobj, scalars, obj_cuts, groups, scalar_cuts, ht, trig):
    """Reference implementation; same signature/returns as
    ``skim.skim_mask`` (numpy arrays in, numpy arrays out)."""
    cols = np.asarray(cols, dtype=np.float32)
    nobj = np.asarray(nobj, dtype=np.float32)
    scalars = np.asarray(scalars, dtype=np.float32)
    obj_cuts = np.asarray(obj_cuts, dtype=np.float32)
    groups = np.asarray(groups, dtype=np.float32)
    scalar_cuts = np.asarray(scalar_cuts, dtype=np.float32)
    ht = np.asarray(ht, dtype=np.float32)
    trig = np.asarray(trig, dtype=np.float32)

    _, b, m = cols.shape
    mask = np.zeros(b, dtype=np.float32)
    stages = np.zeros((skim.N_STAGES, b), dtype=np.float32)

    for ev in range(b):
        # stage 1: preselection
        pre = True
        for k in range(skim.K_SC):
            enabled, col, op, abs_flag, value = scalar_cuts[k]
            if enabled > 0.5:
                x = scalars[int(round(col)), ev]
                pre = pre and bool(_cmp(x, op, value, abs_flag))

        # stage 2: object groups
        obj = True
        for g in range(skim.G):
            enabled, lo, hi, min_count = groups[g]
            if enabled <= 0.5:
                continue
            lo_i, hi_i = int(round(lo)), int(round(hi))
            count = 0
            for slot in range(m):
                covered = False
                ok = True
                for k in range(lo_i, hi_i):
                    if k < 0 or k >= skim.K_OBJ:
                        continue
                    _, col, op, abs_flag, value = obj_cuts[k]
                    ci = int(round(col))
                    if slot >= nobj[ci, ev]:
                        ok = False  # padded slot is not an object
                    covered = True
                    x = cols[ci, ev, slot]
                    if not _cmp(x, op, value, abs_flag):
                        ok = False
                if covered and ok:
                    count += 1
            obj = obj and count >= min_count

        # stage 3: HT
        ht_ok = True
        ht_enabled, ht_col, pt_min, ht_min = ht
        if ht_enabled > 0.5:
            ci = int(round(ht_col))
            total = 0.0
            for slot in range(m):
                if slot < nobj[ci, ev] and cols[ci, ev, slot] > pt_min:
                    total += float(cols[ci, ev, slot])
            ht_ok = total >= ht_min

        # stage 4: trigger OR
        trig_ok = True
        if trig[0] > 0.5:
            trig_ok = any(
                trig[1 + s] > 0.5 and scalars[s, ev] > 0.5 for s in range(skim.S)
            )

        stages[0, ev] = float(pre)
        stages[1, ev] = float(obj)
        stages[2, ev] = float(ht_ok)
        stages[3, ev] = float(trig_ok)
        mask[ev] = float(pre and obj and ht_ok and trig_ok)

    return mask, stages
