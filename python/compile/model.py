"""Layer-2 JAX graph: the multi-stage skim pipeline around the L1
kernel.

The graph mirrors §3.2's structured execution model: the kernel
produces the final event mask plus per-stage masks; the graph derives
the staged survivor counts (how many events each stage would pass on
its own, and cumulatively) that the Rust engine reports, and packs the
outputs the coordinator consumes:

    (mask[B], stages[4,B], stage_counts[4], cum_counts[4], n_pass[1])

Everything is one fused XLA module — the cut bank is an *input*, so one
AOT artifact serves every query that fits the kernel capacities (no
per-query recompilation on the request path).
"""

import jax.numpy as jnp

from .kernels import skim


def skim_filter(cols, nobj, scalars, obj_cuts, groups, scalar_cuts, ht, trig,
                tile_b=skim.TILE_B):
    """Full L2 computation. Shapes as in ``skim.skim_mask``."""
    mask, stages = skim.skim_mask(
        cols, nobj, scalars, obj_cuts, groups, scalar_cuts, ht, trig,
        tile_b=tile_b,
    )
    # Independent per-stage pass counts.
    stage_counts = jnp.sum(stages, axis=1)  # [4]
    # Cumulative survivors after each stage (the §3.2 funnel:
    # preselection → object → HT → trigger).
    cum = jnp.cumprod(stages, axis=0)  # [4, B]
    cum_counts = jnp.sum(cum, axis=1)  # [4]
    n_pass = jnp.sum(mask, keepdims=True)  # [1]
    return mask, stages, stage_counts, cum_counts, n_pass


def reference_filter(cols, nobj, scalars, obj_cuts, groups, scalar_cuts, ht, trig,
                     tile_b=None):
    """Same graph with the kernel body inlined as plain jnp (no
    pallas_call) — used for the L2-level A/B artifact and tests."""
    del tile_b  # the inlined graph has no grid
    mask, stages = skim._evaluate(  # noqa: SLF001 — intentional reuse
        cols, nobj, scalars, obj_cuts, groups, scalar_cuts, ht, trig
    )
    stage_counts = jnp.sum(stages, axis=1)
    cum = jnp.cumprod(stages, axis=0)
    cum_counts = jnp.sum(cum, axis=1)
    n_pass = jnp.sum(mask, keepdims=True)
    return mask, stages, stage_counts, cum_counts, n_pass
