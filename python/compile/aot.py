"""AOT bridge: lower the L2 graph to HLO **text** + a JSON manifest.

HLO text (not ``HloModuleProto.serialize``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla
crate's bundled XLA (xla_extension 0.5.1) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot [--out-dir ../artifacts] [--variant all]

Emits one artifact per batch-shape variant:

    skim_<name>.hlo.txt   — the lowered module
    manifest.json         — shapes, argument order, capacities

``make artifacts`` runs this once; the Rust runtime
(rust/src/runtime/) loads the artifacts and Python never runs again.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import skim

# (name, batch B, max objects M, tile)
#
# Tile note: on real TPU hardware the BlockSpec tiles the batch at 256
# events (VMEM residency, DESIGN.md §Hardware-Adaptation). The CPU
# artifacts are lowered with tile == B (grid = 1): interpret-mode
# Pallas emulates the grid with a host-level loop + dynamic slicing,
# which only adds overhead on CPU-PJRT where there is no VMEM to tile
# for.
VARIANTS = [
    ("small", 256, 8, 256),
    ("large", 2048, 16, 2048),
]


def arg_specs(b, m):
    """ShapeDtypeStructs in the fixed argument order the Rust runtime
    packs (keep in sync with rust/src/runtime/mod.rs)."""
    f32 = jnp.float32
    return [
        jax.ShapeDtypeStruct((skim.C, b, m), f32),        # cols
        jax.ShapeDtypeStruct((skim.C, b), f32),           # nobj
        jax.ShapeDtypeStruct((skim.S, b), f32),           # scalars
        jax.ShapeDtypeStruct((skim.K_OBJ, 5), f32),       # obj_cuts
        jax.ShapeDtypeStruct((skim.G, 4), f32),           # groups
        jax.ShapeDtypeStruct((skim.K_SC, 5), f32),        # scalar_cuts
        jax.ShapeDtypeStruct((4,), f32),                  # ht
        jax.ShapeDtypeStruct((1 + skim.S,), f32),         # trig
    ]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(name, b, m, tile, fn=None):
    fn = fn or model.skim_filter
    specs = arg_specs(b, m)

    def entry(cols, nobj, scalars, obj_cuts, groups, scalar_cuts, ht, trig):
        return fn(cols, nobj, scalars, obj_cuts, groups, scalar_cuts, ht, trig,
                  tile_b=tile)

    lowered = jax.jit(entry).lower(*specs)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--variant", default="all", help="small | large | all")
    ap.add_argument(
        "--graph",
        default="pallas",
        choices=["pallas", "ref"],
        help="lower the Pallas kernel (default) or the inlined jnp "
        "reference graph (A/B artifact)",
    )
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    fn = model.skim_filter if args.graph == "pallas" else model.reference_filter
    suffix = "" if args.graph == "pallas" else "_ref"

    manifest = {
        "format": 1,
        "graph": args.graph,
        "capacities": {
            "C": skim.C,
            "S": skim.S,
            "K_OBJ": skim.K_OBJ,
            "K_SC": skim.K_SC,
            "G": skim.G,
            "N_STAGES": skim.N_STAGES,
        },
        "arg_order": [
            "cols", "nobj", "scalars", "obj_cuts", "groups",
            "scalar_cuts", "ht", "trig",
        ],
        "outputs": ["mask", "stages", "stage_counts", "cum_counts", "n_pass"],
        "variants": {},
    }

    for name, b, m, tile in VARIANTS:
        if args.variant not in ("all", name):
            continue
        hlo = lower_variant(name, b, m, tile, fn)
        fname = f"skim_{name}{suffix}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(hlo)
        manifest["variants"][name] = {"B": b, "M": m, "tile": tile, "file": fname}
        print(f"wrote {path} ({len(hlo)} chars)")

    mpath = os.path.join(args.out_dir, f"manifest{suffix}.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
