"""AOT path checks: lowering produces loadable HLO text whose compiled
execution matches the eager kernel (same PJRT CPU backend the Rust
runtime uses, reached here through jax's own client)."""

import json
import os
import subprocess
import sys

import numpy as np
from jax._src.lib import xla_client as xc

from compile import aot
from compile.kernels import skim

from .test_kernel import make_inputs, make_program


def test_lower_variant_produces_hlo_text():
    hlo = aot.lower_variant("small", 64, 4, 64)
    assert "HloModule" in hlo
    assert "f32[12,64,4]" in hlo  # cols input shape


def test_hlo_text_reparses_like_the_rust_runtime():
    """The Rust runtime loads artifacts with
    ``HloModuleProto::from_text_file``; jaxlib bundles the same text
    parser. Verify the emitted text round-trips through it and keeps
    the module interface (8 params, tupled 5-output root).

    (Execution equivalence of the parsed text is covered by the Rust
    integration test `runtime::tests` against fixtures produced by this
    same lowering — jaxlib 0.8's in-Python client.compile API no longer
    accepts HLO, so the execute check lives on the consumer side.)
    """
    b, m = 64, 4
    hlo = aot.lower_variant("small", b, m, 64)
    mod = xc._xla.hlo_module_from_text(hlo)
    text2 = mod.to_string()
    assert "HloModule" in text2
    # All eight parameters survive with their shapes.
    assert f"f32[12,{b},{m}]" in text2     # cols [C, B, M]
    assert f"f32[12,{b}]" in text2         # nobj
    assert f"f32[16,{b}]" in text2         # scalars
    assert "f32[12,5]" in text2            # obj_cuts bank
    assert "f32[17]" in text2              # trig vector
    # Tupled outputs: mask, stages, stage_counts, cum_counts, n_pass.
    assert f"f32[{b}]" in text2
    assert f"f32[4,{b}]" in text2


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--variant", "small"],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["capacities"]["C"] == skim.C
    assert "small" in manifest["variants"]
    hlo_file = out / manifest["variants"]["small"]["file"]
    assert hlo_file.exists()
    assert "HloModule" in hlo_file.read_text()[:200]
