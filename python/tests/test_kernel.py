"""L1 correctness: the Pallas kernel vs the event-loop oracle.

This is the CORE correctness signal of the build path — hypothesis
sweeps shapes, data distributions and random cut programs, asserting
exact mask agreement (both sides compute 0.0/1.0 in f32; ties on
thresholds are exercised deliberately via quantized values).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, skim


def make_inputs(rng, b, m, quantize=True):
    """Physics-shaped random batch. Quantized values make threshold
    ties reproducible across implementations."""
    cols = rng.exponential(30.0, size=(skim.C, b, m)).astype(np.float32)
    # eta-like signed columns on odd indices
    cols[1::2] = rng.normal(0.0, 2.0, size=cols[1::2].shape)
    if quantize:
        cols = np.round(cols * 4.0) / 4.0
    cols = cols.astype(np.float32)
    nobj = rng.integers(0, m + 1, size=(skim.C, b)).astype(np.float32)
    scalars = np.round(rng.exponential(20.0, size=(skim.S, b)) * 4.0) / 4.0
    # trigger-like 0/1 flags on the back half
    scalars[skim.S // 2 :] = (rng.random(size=(skim.S - skim.S // 2, b)) < 0.3)
    scalars = scalars.astype(np.float32)
    return cols, nobj, scalars


def make_program(rng, n_obj_cuts=None, n_groups=None, n_scalar_cuts=None,
                 use_ht=True, use_trig=True):
    """Random but *valid* cut program (what the Rust planner emits)."""
    p = {k: np.array(v, dtype=np.float32) for k, v in skim.empty_params().items()}
    k_obj = int(rng.integers(0, skim.K_OBJ + 1) if n_obj_cuts is None else n_obj_cuts)
    for k in range(k_obj):
        p["obj_cuts"][k] = [
            1.0,
            rng.integers(0, skim.C),
            rng.integers(0, 6),
            rng.integers(0, 2),
            np.round(rng.uniform(-10, 60) * 4.0) / 4.0,
        ]
    n_g = int(rng.integers(0, skim.G + 1) if n_groups is None else n_groups)
    for g in range(n_g):
        lo = int(rng.integers(0, max(k_obj, 1)))
        hi = int(rng.integers(lo, k_obj + 1))
        p["groups"][g] = [1.0, lo, hi, rng.integers(0, 4)]
    k_sc = int(rng.integers(0, skim.K_SC + 1) if n_scalar_cuts is None else n_scalar_cuts)
    for k in range(k_sc):
        p["scalar_cuts"][k] = [
            1.0,
            rng.integers(0, skim.S),
            rng.integers(0, 6),
            rng.integers(0, 2),
            np.round(rng.uniform(-5, 40) * 4.0) / 4.0,
        ]
    if use_ht and rng.random() < 0.7:
        p["ht"] = np.asarray(
            [1.0, rng.integers(0, skim.C), 25.0, np.round(rng.uniform(0, 300))],
            dtype=np.float32,
        )
    if use_trig and rng.random() < 0.7:
        members = (rng.random(skim.S) < 0.4).astype(np.float32)
        p["trig"] = np.concatenate([[1.0], members]).astype(np.float32)
    return p


def run_both(cols, nobj, scalars, p):
    got_mask, got_stages = skim.skim_mask(
        cols, nobj, scalars, p["obj_cuts"], p["groups"], p["scalar_cuts"],
        p["ht"], p["trig"],
    )
    want_mask, want_stages = ref.skim_mask_ref(
        cols, nobj, scalars, p["obj_cuts"], p["groups"], p["scalar_cuts"],
        p["ht"], p["trig"],
    )
    return (np.asarray(got_mask), np.asarray(got_stages), want_mask, want_stages)


def assert_agree(cols, nobj, scalars, p):
    got_mask, got_stages, want_mask, want_stages = run_both(cols, nobj, scalars, p)
    np.testing.assert_array_equal(got_stages, want_stages)
    np.testing.assert_array_equal(got_mask, want_mask)


def test_empty_program_accepts_everything():
    rng = np.random.default_rng(0)
    cols, nobj, scalars = make_inputs(rng, 64, 4)
    p = {k: np.asarray(v) for k, v in skim.empty_params().items()}
    mask, stages = skim.skim_mask(
        cols, nobj, scalars, p["obj_cuts"], p["groups"], p["scalar_cuts"],
        p["ht"], p["trig"],
    )
    assert np.all(np.asarray(mask) == 1.0)
    assert np.all(np.asarray(stages) == 1.0)


def test_known_object_cut():
    """Hand-checked case: one electron-pt cut, min_count=1."""
    b, m = 4, 3
    cols = np.zeros((skim.C, b, m), np.float32)
    nobj = np.zeros((skim.C, b), np.float32)
    scalars = np.zeros((skim.S, b), np.float32)
    # event 0: [30, 10, -] → passes (30 > 25)
    # event 1: [10, 20, 24] → fails
    # event 2: [] → fails (no objects)
    # event 3: [26, 27, 28] → passes
    cols[0, 0, :2] = [30, 10]
    nobj[0, 0] = 2
    cols[0, 1] = [10, 20, 24]
    nobj[0, 1] = 3
    nobj[0, 2] = 0
    cols[0, 3] = [26, 27, 28]
    nobj[0, 3] = 3
    p = {k: np.array(v, dtype=np.float32) for k, v in skim.empty_params().items()}
    p["obj_cuts"][0] = [1.0, 0, 0, 0, 25.0]  # col 0, '>', 25
    p["groups"][0] = [1.0, 0, 1, 1]          # cuts [0,1), min_count 1
    mask, _ = skim.skim_mask(
        cols, nobj, scalars, p["obj_cuts"], p["groups"], p["scalar_cuts"],
        p["ht"], p["trig"],
    )
    np.testing.assert_array_equal(np.asarray(mask), [1, 0, 0, 1])
    assert_agree(cols, nobj, scalars, p)


def test_known_ht_and_trigger():
    b, m = 3, 4
    cols = np.zeros((skim.C, b, m), np.float32)
    nobj = np.zeros((skim.C, b), np.float32)
    scalars = np.zeros((skim.S, b), np.float32)
    # HT over col 2, pt_min 30, min 100.
    cols[2, 0] = [50, 60, 10, 0]   # HT = 110 → pass
    nobj[2, 0] = 4
    cols[2, 1] = [50, 40, 0, 0]    # HT = 90 → fail
    nobj[2, 1] = 2
    cols[2, 2] = [200, 0, 0, 0]    # but only 0 valid objects → HT 0 → fail
    nobj[2, 2] = 0
    p = {k: np.array(v, dtype=np.float32) for k, v in skim.empty_params().items()}
    p["ht"] = np.asarray([1.0, 2, 30.0, 100.0], np.float32)
    # Trigger on scalar column 5: fires only for event 1.
    scalars[5] = [0, 1, 0]
    trig = np.zeros(1 + skim.S, np.float32)
    trig[0] = 1.0
    trig[1 + 5] = 1.0
    p["trig"] = trig
    mask, stages = skim.skim_mask(
        cols, nobj, scalars, p["obj_cuts"], p["groups"], p["scalar_cuts"],
        p["ht"], p["trig"],
    )
    np.testing.assert_array_equal(np.asarray(stages)[2], [1, 0, 0])  # ht
    np.testing.assert_array_equal(np.asarray(stages)[3], [0, 1, 0])  # trig
    np.testing.assert_array_equal(np.asarray(mask), [0, 0, 0])
    assert_agree(cols, nobj, scalars, p)


def test_multi_stage_funnel_masks_multiply():
    rng = np.random.default_rng(7)
    cols, nobj, scalars = make_inputs(rng, 128, 8)
    p = make_program(np.random.default_rng(8), n_obj_cuts=4, n_groups=2,
                     n_scalar_cuts=2)
    got_mask, got_stages, _, _ = run_both(cols, nobj, scalars, p)
    np.testing.assert_array_equal(got_mask, np.prod(got_stages, axis=0))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.sampled_from([8, 32, 64]),
    m=st.sampled_from([1, 3, 8, 16]),
)
def test_hypothesis_kernel_matches_ref(seed, b, m):
    rng = np.random.default_rng(seed)
    cols, nobj, scalars = make_inputs(rng, b, m)
    p = make_program(rng)
    assert_agree(cols, nobj, scalars, p)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_threshold_ties(seed):
    """Values exactly at thresholds: >, >=, ==, != must all agree."""
    rng = np.random.default_rng(seed)
    b, m = 16, 4
    cols = np.full((skim.C, b, m), 25.0, np.float32)
    nobj = np.full((skim.C, b), m, np.float32)
    scalars = np.full((skim.S, b), 1.0, np.float32)
    p = {k: np.array(v, dtype=np.float32) for k, v in skim.empty_params().items()}
    op = rng.integers(0, 6)
    p["obj_cuts"][0] = [1.0, 0, op, 0, 25.0]
    p["groups"][0] = [1.0, 0, 1, 1]
    assert_agree(cols, nobj, scalars, p)


def test_batch_not_divisible_by_tile_asserts():
    rng = np.random.default_rng(1)
    cols, nobj, scalars = make_inputs(rng, 24, 2)  # 24 % 256 != 0 → tile=24 ok
    p = {k: np.asarray(v) for k, v in skim.empty_params().items()}
    # tile_b larger than batch clamps to batch — must not raise.
    mask, _ = skim.skim_mask(
        cols, nobj, scalars, p["obj_cuts"], p["groups"], p["scalar_cuts"],
        p["ht"], p["trig"],
    )
    assert np.asarray(mask).shape == (24,)
    with pytest.raises(AssertionError):
        skim.skim_mask(
            cols, nobj, scalars, p["obj_cuts"], p["groups"], p["scalar_cuts"],
            p["ht"], p["trig"], tile_b=7,
        )


def test_tiling_invariance():
    """Same result regardless of grid tiling."""
    rng = np.random.default_rng(3)
    cols, nobj, scalars = make_inputs(rng, 64, 4)
    p = make_program(np.random.default_rng(4), n_obj_cuts=3, n_groups=1)
    outs = []
    for tile in [8, 16, 32, 64]:
        mask, stages = skim.skim_mask(
            cols, nobj, scalars, p["obj_cuts"], p["groups"], p["scalar_cuts"],
            p["ht"], p["trig"], tile_b=tile,
        )
        outs.append((np.asarray(mask), np.asarray(stages)))
    for mask, stages in outs[1:]:
        np.testing.assert_array_equal(mask, outs[0][0])
        np.testing.assert_array_equal(stages, outs[0][1])
