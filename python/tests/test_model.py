"""L2 checks: the model graph's derived outputs and the pallas-vs-ref
graph equivalence."""

import numpy as np

from compile import model
from compile.kernels import skim

from .test_kernel import make_inputs, make_program


def run(fn, cols, nobj, scalars, p):
    return [
        np.asarray(x)
        for x in fn(
            cols, nobj, scalars, p["obj_cuts"], p["groups"], p["scalar_cuts"],
            p["ht"], p["trig"],
        )
    ]


def test_model_outputs_consistent():
    rng = np.random.default_rng(11)
    cols, nobj, scalars = make_inputs(rng, 64, 8)
    p = make_program(np.random.default_rng(12), n_obj_cuts=4, n_groups=2,
                     n_scalar_cuts=1)
    mask, stages, stage_counts, cum_counts, n_pass = run(
        model.skim_filter, cols, nobj, scalars, p
    )
    assert mask.shape == (64,)
    assert stages.shape == (skim.N_STAGES, 64)
    np.testing.assert_allclose(stage_counts, stages.sum(axis=1))
    np.testing.assert_allclose(cum_counts, np.cumprod(stages, axis=0).sum(axis=1))
    np.testing.assert_allclose(n_pass, [mask.sum()])
    # The funnel is monotone non-increasing.
    assert all(cum_counts[i] >= cum_counts[i + 1] for i in range(3))
    # Final survivors == last funnel stage.
    np.testing.assert_allclose(n_pass[0], cum_counts[-1])


def test_pallas_graph_equals_reference_graph():
    rng = np.random.default_rng(21)
    for seed in range(5):
        prng = np.random.default_rng(100 + seed)
        cols, nobj, scalars = make_inputs(rng, 32, 4)
        p = make_program(prng)
        got = run(model.skim_filter, cols, nobj, scalars, p)
        want = run(model.reference_filter, cols, nobj, scalars, p)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w)
